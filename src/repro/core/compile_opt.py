"""Pipeline compilation: conceptual -> logical rewriting (paper §3).

The paper: "consider a transformer Retriever(index, k) that has a rank
cutoff operation (%k') applied.  A more efficient pipeline formulation
would be to apply the rank cutoff directly in the Retriever instance.
PyTerrier supports a number of such optional compile operations, which
allow applying a rewriting of the conceptual pipeline into a more
efficient logical variant — a syntactically different but semantically
equivalent reformulation that executes more quickly."  (Their footnote:
akin to SQL selection pushdown.)

Rewrites implemented (each provably semantics-preserving, see tests):

1. **cutoff pushdown** — ``Retriever(num_results=N) >> %k`` with k <= N
   becomes ``Retriever(num_results=k)`` (the retriever's own top-k
   pruning does less scoring/sorting work);
2. **cutoff fusion** — ``%k1 >> %k2`` becomes ``%min(k1,k2)``;
3. **identity elision** — ``Identity()`` stages are dropped;
4. **cutoff/rewrite reorder is NOT applied** across non-R->R stages
   (a cutoff cannot cross a stage that changes scores), mirroring the
   paper's caution that pipelines are affected by their leftmost
   constituent.

``compile_pipeline`` composes with prefix precomputation: Experiment
can compile each pipeline first and share the compiled prefixes.
"""
from __future__ import annotations

from typing import List, Optional

from .pipeline import Compose, Identity, RankCutoff, Transformer, stages_of

__all__ = ["compile_pipeline"]


def _clone_with_num_results(retriever, k: int):
    """Best-effort: retrievers expose num_results + a copy path."""
    import copy
    new = copy.copy(retriever)
    new.num_results = int(k)
    return new


def compile_pipeline(pipeline: Transformer) -> Transformer:
    """Rewrite a pipeline into an equivalent, cheaper logical plan."""
    stages = list(stages_of(pipeline))
    out: List[Transformer] = []
    for stage in stages:
        # 3. identity elision
        if isinstance(stage, Identity):
            continue
        if isinstance(stage, RankCutoff) and out:
            prev = out[-1]
            # 2. cutoff fusion
            if isinstance(prev, RankCutoff):
                out[-1] = RankCutoff(min(prev.k, stage.k))
                continue
            # 1. cutoff pushdown into a retriever
            if hasattr(prev, "num_results") and \
                    getattr(prev, "one_to_many", False) and \
                    stage.k <= prev.num_results:
                out[-1] = _clone_with_num_results(prev, stage.k)
                continue
        out.append(stage)
    if not out:
        return Identity()
    if len(out) == 1:
        return out[0]
    return Compose(out)
