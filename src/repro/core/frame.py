"""Column-oriented relation store (the Q/D/R/RA data model).

The paper's platform instantiates relations as pandas DataFrames or lists
of dictionaries.  pandas is not available in this environment, so we
provide ``ColFrame`` — a small, fast, numpy-backed column store with the
relational operations the pipeline algebra needs (select, concat, sort,
group-by, hash join, key-based dedup).  Transformers accept and return
``ColFrame`` (and, like the paper's platform, lists of dicts are mapped
in/out transparently).

Relation types (extensible — extra columns always allowed):
  Q  (qid, query)
  D  (docno, text, ...)
  R  (qid, docno, score, rank, ...)
  RA (qid, docno, label)
"""
from __future__ import annotations

from typing import Any, Dict, Iterable, List, Mapping, Optional, Sequence, Tuple

import numpy as np

__all__ = ["ColFrame", "Q", "D", "R", "RA", "relation_of"]

# Canonical relation signatures (required columns).
Q = frozenset({"qid", "query"})
D = frozenset({"docno", "text"})
R = frozenset({"qid", "docno", "score", "rank"})
RA = frozenset({"qid", "docno", "label"})

_RELATION_NAMES = [("R", R), ("RA", RA), ("Q", Q), ("D", D)]


def relation_of(frame: "ColFrame") -> Optional[str]:
    """Best-effort classification of a frame into Q/D/R/RA."""
    cols = set(frame.columns)
    for name, req in _RELATION_NAMES:
        if req <= cols:
            return name
    return None


def _as_column(values: Any, length: Optional[int] = None) -> np.ndarray:
    if isinstance(values, np.ndarray):
        arr = values
    elif np.isscalar(values) or isinstance(values, str):
        if length is None:
            raise ValueError("scalar column requires a known frame length")
        if isinstance(values, str):
            arr = np.empty(length, dtype=object)
            arr[:] = values
        else:
            arr = np.full(length, values)
        return arr
    else:
        values = list(values)
        if values and isinstance(values[0], str):
            arr = np.empty(len(values), dtype=object)
            arr[:] = values
        else:
            arr = np.asarray(values)
    if arr.dtype.kind in ("U", "S"):
        obj = np.empty(arr.shape[0], dtype=object)
        obj[:] = arr.tolist()
        arr = obj
    return arr


class ColFrame:
    """An ordered, column-oriented relation."""

    __slots__ = ("_cols", "_len")

    def __init__(self, data: Optional[Mapping[str, Any]] = None, *, _unsafe=None):
        if _unsafe is not None:
            self._cols = _unsafe
            self._len = len(next(iter(_unsafe.values()))) if _unsafe else 0
            return
        self._cols: Dict[str, np.ndarray] = {}
        self._len = 0
        if data:
            lengths = [len(v) for v in data.values()
                       if isinstance(v, (np.ndarray, list, tuple))]
            n = lengths[0] if lengths else 0
            for name, values in data.items():
                col = _as_column(values, length=n)
                if self._cols and len(col) != self._len:
                    raise ValueError(
                        f"column {name!r} has length {len(col)}, expected {self._len}")
                self._cols[name] = col
                self._len = len(col)

    # -- construction -------------------------------------------------
    @classmethod
    def from_dicts(cls, rows: Iterable[Mapping[str, Any]]) -> "ColFrame":
        rows = list(rows)
        if not rows:
            return cls()
        cols: Dict[str, list] = {k: [] for k in rows[0].keys()}
        for r in rows:
            for k in cols:
                cols[k].append(r.get(k))
        return cls({k: v for k, v in cols.items()})

    @classmethod
    def coerce(cls, obj: Any) -> "ColFrame":
        if isinstance(obj, ColFrame):
            return obj
        if isinstance(obj, Mapping):
            return cls(obj)
        if isinstance(obj, (list, tuple)):
            return cls.from_dicts(obj)
        raise TypeError(f"cannot coerce {type(obj).__name__} to ColFrame")

    @classmethod
    def empty(cls, columns: Sequence[str]) -> "ColFrame":
        return cls({c: np.empty(0, dtype=object) for c in columns})

    # -- basics --------------------------------------------------------
    def __len__(self) -> int:
        return self._len

    @property
    def columns(self) -> Tuple[str, ...]:
        return tuple(self._cols.keys())

    def __contains__(self, col: str) -> bool:
        return col in self._cols

    def __getitem__(self, col: str) -> np.ndarray:
        return self._cols[col]

    def get(self, col: str, default=None):
        return self._cols.get(col, default)

    def to_dicts(self) -> List[Dict[str, Any]]:
        names = self.columns
        cols = [self._cols[n] for n in names]
        return [dict(zip(names, vals)) for vals in zip(*[c.tolist() for c in cols])] \
            if self._len else []

    def copy(self) -> "ColFrame":
        return ColFrame(_unsafe={k: v.copy() for k, v in self._cols.items()})

    def __repr__(self) -> str:
        return f"ColFrame({self._len} rows × {list(self.columns)})"

    # -- row/column algebra ---------------------------------------------
    def take(self, idx: np.ndarray) -> "ColFrame":
        idx = np.asarray(idx)
        return ColFrame(_unsafe={k: v[idx] for k, v in self._cols.items()})

    def head(self, n: int) -> "ColFrame":
        return self.take(np.arange(min(n, self._len)))

    def mask(self, m: np.ndarray) -> "ColFrame":
        return self.take(np.nonzero(np.asarray(m))[0])

    def select(self, cols: Sequence[str]) -> "ColFrame":
        return ColFrame(_unsafe={c: self._cols[c] for c in cols})

    def drop(self, cols: Sequence[str]) -> "ColFrame":
        cols = set(cols)
        return ColFrame(_unsafe={k: v for k, v in self._cols.items()
                                 if k not in cols})

    def assign(self, **newcols: Any) -> "ColFrame":
        out = dict(self._cols)
        for name, values in newcols.items():
            out[name] = _as_column(values, length=self._len)
            if len(out[name]) != self._len and self._cols:
                raise ValueError(f"assign({name}): bad length")
        return ColFrame(_unsafe=out)

    def rename(self, mapping: Mapping[str, str]) -> "ColFrame":
        return ColFrame(_unsafe={mapping.get(k, k): v
                                 for k, v in self._cols.items()})

    # -- ordering -------------------------------------------------------
    def sort_values(self, by: Sequence[str], ascending=True) -> "ColFrame":
        if isinstance(by, str):
            by = [by]
        if isinstance(ascending, bool):
            ascending = [ascending] * len(by)
        keys = []
        # np.lexsort sorts by the LAST key first.
        for col, asc in zip(reversed(by), reversed(list(ascending))):
            arr = self._cols[col]
            if arr.dtype == object:
                # factorize strings for lexsort
                uniq, inv = np.unique(arr.astype(str), return_inverse=True)
                arr = inv
            keys.append(arr if asc else -arr)
        order = np.lexsort(keys) if keys else np.arange(self._len)
        return self.take(order)

    # -- grouping -------------------------------------------------------
    def group_indices(self, by: Sequence[str]) -> Dict[Tuple, np.ndarray]:
        """Stable mapping group-key-tuple -> row indices."""
        if isinstance(by, str):
            by = [by]
        if self._len == 0:
            return {}
        key_cols = [self._cols[c] for c in by]
        codes = _row_codes(key_cols)
        order = np.argsort(codes, kind="stable")
        sorted_codes = codes[order]
        boundaries = np.nonzero(np.diff(sorted_codes))[0] + 1
        splits = np.split(order, boundaries)
        out: Dict[Tuple, np.ndarray] = {}
        for idxs in splits:
            i0 = idxs[0]
            key = tuple(c[i0] for c in key_cols)
            out[key] = idxs
        return out

    # -- key utilities ----------------------------------------------------
    def key_tuples(self, by: Sequence[str]) -> List[Tuple]:
        if isinstance(by, str):
            by = [by]
        cols = [self._cols[c].tolist() for c in by]
        return list(zip(*cols)) if self._len else []

    def dedup(self, by: Sequence[str], keep: str = "first") -> "ColFrame":
        keys = self.key_tuples(by)
        seen: Dict[Tuple, int] = {}
        for i, k in enumerate(keys):
            if keep == "first":
                seen.setdefault(k, i)
            else:
                seen[k] = i
        idx = np.array(sorted(seen.values()), dtype=np.int64)
        return self.take(idx)

    # -- concat / join -----------------------------------------------------
    @staticmethod
    def concat(frames: Sequence["ColFrame"]) -> "ColFrame":
        frames = [f for f in frames if len(f)]
        if not frames:
            return ColFrame()
        cols = list(frames[0].columns)
        common = [c for c in cols if all(c in f for f in frames)]
        out = {}
        for c in common:
            parts = [f[c] for f in frames]
            if any(p.dtype == object for p in parts):
                merged = np.empty(sum(len(p) for p in parts), dtype=object)
                ofs = 0
                for p in parts:
                    merged[ofs:ofs + len(p)] = p
                    ofs += len(p)
                out[c] = merged
            else:
                out[c] = np.concatenate(parts)
        return ColFrame(_unsafe=out)

    def merge(self, other: "ColFrame", on: Sequence[str],
              how: str = "inner", suffix: str = "_r") -> "ColFrame":
        """Hash join (left keys -> first matching right row)."""
        if isinstance(on, str):
            on = [on]
        rkeys = {}
        for j, k in enumerate(other.key_tuples(on)):
            rkeys.setdefault(k, j)
        lidx, ridx, matched = [], [], []
        for i, k in enumerate(self.key_tuples(on)):
            j = rkeys.get(k)
            if j is not None:
                lidx.append(i)
                ridx.append(j)
                matched.append(True)
            elif how == "left":
                lidx.append(i)
                ridx.append(-1)
                matched.append(False)
        lidx = np.asarray(lidx, dtype=np.int64)
        ridx = np.asarray(ridx, dtype=np.int64)
        matched = np.asarray(matched, dtype=bool)
        out = {k: v[lidx] if len(lidx) else np.empty(0, dtype=v.dtype)
               for k, v in self._cols.items()}
        for k, v in other._cols.items():
            if k in on:
                continue
            name = k if k not in out else k + suffix
            if len(ridx):
                col = v[np.where(ridx >= 0, ridx, 0)]
                if how == "left" and not matched.all():
                    col = col.astype(object)
                    col[~matched] = None
            else:
                col = np.empty(0, dtype=v.dtype)
            out[name] = col
        return ColFrame(_unsafe=out)

    # -- equality (used in tests: cache transparency invariant) -----------
    def equals(self, other: "ColFrame", cols: Optional[Sequence[str]] = None,
               rtol: float = 1e-6, atol: float = 1e-6) -> bool:
        cols = list(cols or self.columns)
        if any(c not in other for c in cols) or len(self) != len(other):
            return False
        for c in cols:
            a, b = self._cols[c], other[c]
            if a.dtype == object or b.dtype == object:
                if not all(x == y for x, y in zip(a.tolist(), b.tolist())):
                    return False
            elif np.issubdtype(a.dtype, np.floating):
                if not np.allclose(a, b.astype(a.dtype), rtol=rtol, atol=atol):
                    return False
            else:
                if not np.array_equal(a, b):
                    return False
        return True


def _row_codes(key_cols: List[np.ndarray]) -> np.ndarray:
    """Integer codes identifying distinct key tuples."""
    code = np.zeros(len(key_cols[0]), dtype=np.int64)
    mult = 1
    for col in reversed(key_cols):
        if col.dtype == object:
            _, inv = np.unique(col.astype(str), return_inverse=True)
        else:
            _, inv = np.unique(col, return_inverse=True)
        code = code + inv.astype(np.int64) * mult
        mult *= int(inv.max(initial=0)) + 1
    return code
