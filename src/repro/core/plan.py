"""Unified experiment planner: pipelines lowered into one shared DAG.

The paper develops two complementary directions: *implicit* prefix
sharing inside ``Experiment`` (§3 — the LCP of Eq. 2, generalized to a
prefix trie for the §6 ablation limitation) and *explicit* operation
caches applied by hand (§4).  ``ExecutionPlan`` unifies both behind a
single abstraction, following the "Trie-based Experiment Plans"
follow-up (PAPERS.md): a set of pipelines is **lowered** into one DAG
whose nodes are deduplicated by structural signature, then executed in
dependency order with each node run exactly once.

Improvements over the stage-list trie of ``precompute.py``:

* **Sharing through operator nodes** (§6 limitation, resolved): the
  planner recurses into binary operators (``LinearCombine``,
  ``FeatureUnion``, ``SetUnion``, ``SetIntersection``, ``Concatenate``)
  and ``ScalarProduct``, so a retriever shared under ``a + b`` and
  ``a ** c`` executes once.  ``stages_of`` treats those nodes as opaque
  and re-executes ``a`` per pipeline.
* **Planner-inserted memoization** (§4 + §6 future work): with a
  ``cache_dir``, every node whose transformer declares sufficient
  ``auto_cache`` metadata gets the matching explicit cache family
  (KeyValueCache / ScorerCache / RetrieverCache) wrapped around it by
  the planner — researchers no longer hand-wrap stages (§4's usability
  caveat).  A custom ``memo_factory`` makes the policy pluggable.
* **Plan-level accounting**: ``PlanStats`` extends ``PrecomputeStats``
  with planned/executed node counts, cache hit/miss totals and
  per-node wall times, surfaced through ``Experiment`` results and
  ``benchmarks/plan_bench.py``.

``run_with_precompute``, ``run_with_trie`` and ``Experiment`` are thin
wrappers over this module — the planner is the single execution path.
"""
from __future__ import annotations

import hashlib
import os
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from .frame import ColFrame
from .pipeline import (Compose, ScalarProduct, Transformer, _Binary,
                       pipeline_hash)
from .precompute import (PrecomputeStats, _run_stage, longest_common_prefix)

__all__ = ["ExecutionPlan", "PlanNode", "PlanStats", "plan_size"]


@dataclass
class PlanStats(PrecomputeStats):
    """Per-run accounting of a plan execution."""
    nodes_planned: int = 0               # unique DAG nodes (excl. source)
    cache_hits: int = 0                  # memo hits across inserted caches
    cache_misses: int = 0
    node_times_s: Dict[str, float] = field(default_factory=dict)
    wall_time_s: float = 0.0

    def __str__(self) -> str:
        return (f"PlanStats(planned={self.nodes_planned} "
                f"executed={self.nodes_executed} "
                f"naive={self.nodes_total} "
                f"saved={self.stage_invocations_saved} "
                f"cache_hits={self.cache_hits} "
                f"wall={self.wall_time_s:.3f}s)")


@dataclass
class PlanNode:
    """One deduplicated unit of work in the DAG."""
    key: Tuple                           # canonical structural key
    kind: str                            # "source" | "stage" | "combine" | "scale"
    stage: Optional[Transformer]         # operator instance (None for source)
    inputs: List["PlanNode"] = field(default_factory=list)
    cache: Optional[Transformer] = None  # planner-inserted memo wrapper
    label: str = ""                      # unique display label (see _label_nodes)


def plan_size(expr: Transformer) -> int:
    """Stage invocations of one *naive* execution of ``expr`` (binary
    operators expand into 1 + both children, unlike ``stages_of``)."""
    if isinstance(expr, Compose):
        return sum(plan_size(s) for s in expr.stages)
    if isinstance(expr, _Binary):
        return 1 + plan_size(expr.left) + plan_size(expr.right)
    if isinstance(expr, ScalarProduct):
        return 1 + plan_size(expr.inner)
    return 1


class ExecutionPlan:
    """Lower a pipeline set into a shared DAG and execute it.

    Parameters
    ----------
    pipelines:
        The systems of an experiment (operator-algebra expressions).
    cache_dir:
        When given, enables planner-inserted memoization: each eligible
        node gets an explicit cache (selected by ``auto_cache`` from the
        node's metadata) rooted under this directory, so repeated runs —
        or overlapping plans pointed at the same directory — hit.
    memo_factory:
        Pluggable cache policy ``(transformer, path) -> wrapper | None``.
        Defaults to ``repro.caching.auto.auto_cache`` with uncacheable
        stages (per §5, e.g. DuoT5-style scorers) left bare.
    """

    def __init__(self, pipelines: Sequence[Transformer], *,
                 cache_dir: Optional[str] = None,
                 memo_factory: Optional[Callable[..., Any]] = None):
        self.pipelines: List[Transformer] = list(pipelines)
        self.cache_dir = cache_dir
        self._memo_factory = memo_factory
        self.source = PlanNode(key=("source",), kind="source", stage=None)
        self.nodes: Dict[Tuple, PlanNode] = {self.source.key: self.source}
        self.terminals: List[PlanNode] = [
            self._lower(p, self.source) for p in self.pipelines]
        self.nodes_total_naive = sum(plan_size(p) for p in self.pipelines)
        self._label_nodes()
        if cache_dir is not None or memo_factory is not None:
            self._insert_memos()
        self.stats: Optional[PlanStats] = None   # last run

    def _label_nodes(self) -> None:
        """Unique display labels: the same stage planned under two
        different prefixes is two nodes and must not share a
        ``node_times_s`` entry."""
        seen: Dict[str, int] = {}
        for node in self.nodes.values():
            if node.kind == "source":
                node.label = "<source>"
                continue
            base = repr(node.stage)
            k = seen.get(base, 0)
            seen[base] = k + 1
            node.label = base if k == 0 else f"{base}#{k}"

    # -- lowering ----------------------------------------------------------
    def _node(self, key: Tuple, kind: str, stage: Transformer,
              inputs: List[PlanNode]) -> PlanNode:
        node = self.nodes.get(key)
        if node is None:
            node = PlanNode(key=key, kind=kind, stage=stage, inputs=inputs)
            self.nodes[key] = node
        return node

    def _lower(self, expr: Transformer, inp: PlanNode) -> PlanNode:
        """Recursively lower ``expr`` applied to ``inp``'s result."""
        if isinstance(expr, Compose):
            node = inp
            for stage in expr.stages:
                node = self._lower(stage, node)
            return node
        if isinstance(expr, _Binary):
            left = self._lower(expr.left, inp)
            right = self._lower(expr.right, inp)
            key = ("combine", type(expr).__name__, left.key, right.key)
            return self._node(key, "combine", expr, [left, right])
        if isinstance(expr, ScalarProduct):
            inner = self._lower(expr.inner, inp)
            key = ("scale", expr.scalar, inner.key)
            return self._node(key, "scale", expr, [inner])
        key = ("stage", expr.signature(), inp.key)
        return self._node(key, "stage", expr, [inp])

    # -- planner-inserted memoization --------------------------------------
    def _insert_memos(self) -> None:
        factory = self._memo_factory
        if factory is None:
            from ..caching.auto import auto_cache_or_none
            factory = auto_cache_or_none
        for node in self.nodes.values():
            if node.kind != "stage":
                continue
            path = None
            if self.cache_dir is not None:
                # key the store by the node's full structural position so
                # the same stage under different prefixes never collides;
                # sha256 (not hash()) so the path is stable across processes
                digest = hashlib.sha256(
                    repr(node.key).encode()).hexdigest()[:16]
                path = os.path.join(
                    self.cache_dir, pipeline_hash(node.stage) + "-" + digest)
            node.cache = factory(node.stage, path)

    def close(self) -> None:
        """Close planner-inserted caches (flushes temporary stores)."""
        for node in self.nodes.values():
            if node.cache is not None and hasattr(node.cache, "close"):
                node.cache.close()

    def __enter__(self) -> "ExecutionPlan":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False

    # -- analysis ----------------------------------------------------------
    def n_nodes(self) -> int:
        return len(self.nodes) - 1       # exclude the source

    # -- execution ---------------------------------------------------------
    def run(self, queries: Any, *, batch_size: Optional[int] = None
            ) -> Tuple[List[ColFrame], PlanStats]:
        """Execute the DAG once over ``queries``.

        Every node runs at most once; results are identical to naive
        per-pipeline execution (the cache-transparency invariant,
        asserted in tests/test_plan.py).
        """
        t0 = time.perf_counter()
        cache_base = self._cache_counters()
        results: Dict[Tuple, ColFrame] = {
            self.source.key: ColFrame.coerce(queries)}
        stats = PlanStats(
            prefix_len=len(longest_common_prefix(self.pipelines)),
            n_pipelines=len(self.pipelines),
            nodes_total=self.nodes_total_naive,
            nodes_planned=self.n_nodes())

        def evaluate(node: PlanNode) -> ColFrame:
            memo = results.get(node.key)
            if memo is not None:
                return memo
            ins = [evaluate(i) for i in node.inputs]
            t1 = time.perf_counter()
            if node.kind == "stage":
                runner = node.cache if node.cache is not None else node.stage
                out = _run_stage(runner, ins[0], batch_size)
            elif node.kind == "scale":
                out = node.stage.apply(ins[0])
            else:                                       # combine
                out = node.stage.combine(ins[0], ins[1])
            stats.nodes_executed += 1
            stats.node_times_s[node.label] = \
                stats.node_times_s.get(node.label, 0.0) + \
                (time.perf_counter() - t1)
            results[node.key] = out
            return out

        outs = [evaluate(t) for t in self.terminals]
        stats.stage_invocations_saved = \
            stats.nodes_total - stats.nodes_executed
        hits, misses = self._cache_counters()
        stats.cache_hits = hits - cache_base[0]
        stats.cache_misses = misses - cache_base[1]
        stats.wall_time_s = time.perf_counter() - t0
        self.stats = stats
        return outs, stats

    def _cache_counters(self) -> Tuple[int, int]:
        hits = misses = 0
        for node in self.nodes.values():
            cs = getattr(node.cache, "stats", None)
            if cs is not None:
                hits += cs.hits
                misses += cs.misses
        return hits, misses
