"""ExecutionPlan — façade over the plan compiler.

The paper develops two complementary directions: *implicit* prefix
sharing inside ``Experiment`` (§3 — the LCP of Eq. 2, generalized to a
prefix trie for the §6 ablation limitation) and *explicit* operation
caches applied by hand (§4).  ``ExecutionPlan`` unifies both behind a
single abstraction, following the "Trie-based Experiment Plans"
follow-up (PAPERS.md), and is now a thin façade over a three-layer
compiler:

* **logical IR** (``core/ir.py``) — pipelines lower into a DAG forest,
  one node per operator occurrence, with relation types and
  ``shardable`` / ``rank_preserving`` / ``augment_only`` metadata
  lifted from ``Transformer``;
* **optimizer** (``core/rewrite.py``) — an ordered pass pipeline
  selected by ``optimize=``: algebraic normalization (commutative
  operands canonicalized), cross-pipeline CSE (identical subtrees
  *anywhere* in the DAG execute once — beyond prefixes, the §6
  resolution), ``RankCutoff`` pushdown into retriever ``num_results``
  through rank-preserving stages, and cache-aware pruning that consults
  the provenance manifests to defer work upstream of warm memo nodes;
* **physical executor** (``core/executor.py``) — the sequential and
  sharded-wavefront schedulers, semantics unchanged.

``optimize="all"`` (default) preserves the sharing behaviour of earlier
revisions; ``optimize="none"`` executes the naive forest (the paper's
baseline); a list of pass names runs exactly those passes in order.
The hard invariant — property-tested in ``tests/test_rewrite.py`` — is
that optimizer-on and optimizer-off produce bit-identical per-qid
results under both schedulers.

``explain()`` renders the optimized plan as an ASCII tree (per-node
fingerprint, cache family, which pass touched it); the same record is
persisted in the plan manifest so ``repro plan explain`` round-trips
the output from disk.

``run_with_precompute``, ``run_with_trie`` and ``Experiment`` remain
thin wrappers over this module — the planner is the single execution
path.
"""
from __future__ import annotations

import hashlib
import inspect
import os
import time
from dataclasses import dataclass, field
from typing import (Any, Callable, Dict, List, Optional, Sequence, Tuple,
                    Union)

from .cost import (CostContext, CostModel, annotate_node_actuals,
                   compute_node_fingerprints, fold_costs, should_prefetch,
                   _round_cost)
from .executor import (_Recorder, resolve_n_shards, run_concurrent,
                       run_sequential, run_warm)
from .frame import ColFrame
from .ir import IRNode, PlanGraph, lower, plan_size, render_explain
from .pipeline import Transformer, pipeline_hash
from .precompute import PrecomputeStats, longest_common_prefix
from .rewrite import (PLACEMENT_PASSES, POST_MEMO_PASSES, PassStats,
                      resolve_passes, run_pass)

__all__ = ["ExecutionPlan", "PlanNode", "PlanStats", "plan_size"]

#: backwards-compatible alias — plan nodes are IR nodes now
PlanNode = IRNode


@dataclass
class PlanStats(PrecomputeStats):
    """Per-run accounting of a plan execution."""
    nodes_planned: int = 0               # unique DAG nodes (excl. source)
    cache_hits: int = 0                  # memo hits across inserted caches
    cache_misses: int = 0
    #: subset of ``cache_hits`` served from the I/O-pool staging map
    #: (``caching/dataplane.py``) — attributed to the consuming node at
    #: consumption time, so hits+misses stay exactly the request count
    cache_prefetched: int = 0
    node_times_s: Dict[str, float] = field(default_factory=dict)
    node_exec_counts: Dict[str, int] = field(default_factory=dict)
    #: raw wrapped-transformer seconds (and the queries they covered)
    #: spent on cached nodes' miss paths this run — the recompute cost
    #: the fingerprint-keyed EWMA folds for cached nodes, since their
    #: ``node_times_s`` is dominated by store round trips (see
    #: ``caching.base.CacheStats.compute_s``)
    node_compute_s: Dict[str, float] = field(default_factory=dict)
    node_compute_queries: Dict[str, int] = field(default_factory=dict)
    wall_time_s: float = 0.0
    n_queries: int = 0                   # rows in the query frame
    # -- online serving (filled by PipelineService, see serve/service.py) ----
    #: per-node online latency (p50/p99 ms), executions and rows, plus
    #: service-level queue depth / flush-trigger / batch-occupancy stats
    online: Dict[str, Any] = field(default_factory=dict)
    # -- optimizer ----------------------------------------------------------
    optimizer_passes: List[str] = field(default_factory=list)
    nodes_eliminated: int = 0            # removed by normalize+cse/pushdown
    cutoffs_pushed: int = 0              # RankCutoffs absorbed or moved
    nodes_pruned: int = 0                # warm-cache deferred nodes skipped
    pass_times_s: Dict[str, float] = field(default_factory=dict)
    # -- concurrent executor -------------------------------------------------
    n_shards: int = 1                    # query-frame partitions executed
    n_workers: int = 1                   # thread-pool size
    shard_times_s: List[float] = field(default_factory=list)
    occupancy: float = 0.0               # busy-time / (workers × wall)
    speedup_vs_sequential: Optional[float] = None  # filled by benchmarks

    def __str__(self) -> str:
        extra = ""
        if self.n_shards > 1 or self.n_workers > 1:
            extra = (f" shards={self.n_shards} workers={self.n_workers} "
                     f"occupancy={self.occupancy:.2f}")
        opt = ""
        if self.nodes_eliminated or self.cutoffs_pushed or self.nodes_pruned:
            opt = (f" eliminated={self.nodes_eliminated} "
                   f"pushed={self.cutoffs_pushed} "
                   f"pruned={self.nodes_pruned}")
        return (f"PlanStats(planned={self.nodes_planned} "
                f"executed={self.nodes_executed} "
                f"naive={self.nodes_total} "
                f"saved={self.stage_invocations_saved} "
                f"cache_hits={self.cache_hits} "
                f"wall={self.wall_time_s:.3f}s{opt}{extra})")


def _accepted_kwargs(factory: Callable[..., Any],
                     wanted: Dict[str, Any]) -> Dict[str, Any]:
    """The subset of ``wanted`` that ``factory`` can accept — custom
    memo factories keep their minimal ``(stage, path)`` signature while
    richer ones opt into ``backend`` / ``fingerprint`` / ``on_stale``."""
    try:
        params = inspect.signature(factory).parameters.values()
    except (TypeError, ValueError):      # builtins / C callables
        return {}
    if any(p.kind == p.VAR_KEYWORD for p in params):
        return dict(wanted)
    names = {p.name for p in params
             if p.kind in (p.POSITIONAL_OR_KEYWORD, p.KEYWORD_ONLY)}
    return {k: v for k, v in wanted.items() if k in names}


class ExecutionPlan:
    """Lower a pipeline set into a shared DAG, optimize it, execute it.

    Parameters
    ----------
    pipelines:
        The systems of an experiment (operator-algebra expressions).
    cache_dir:
        When given, enables planner-inserted memoization: each eligible
        node gets an explicit cache (selected by ``auto_cache`` from the
        node's metadata) rooted under this directory, so repeated runs —
        or overlapping plans pointed at the same directory — hit.
    cache_backend:
        Storage backend for planner-inserted caches, by registry name
        (``"memory"`` / ``"pickle"`` / ``"dbm"`` / ``"sqlite"`` — see
        ``caching/backends.py``).  ``None`` keeps each cache family's
        default.  ``cache_backend="memory"`` alone (no ``cache_dir``)
        enables purely in-process memoization.
    memo_factory:
        Pluggable cache policy ``(transformer, path, **kw) -> wrapper |
        None``.  Defaults to ``repro.caching.auto.auto_cache_or_none``
        with uncacheable stages (per §5, e.g. DuoT5-style scorers) left
        bare.  Factories that accept them also receive ``fingerprint=``
        (the node's provenance fingerprint) and ``on_stale=``.
    on_stale:
        Policy when a node's cache directory records a different
        provenance fingerprint (``caching/provenance.py``): ``"error"``
        (default — raise ``StaleCacheError``), ``"recompute"`` (discard
        the stale entries) or ``"readonly"`` (serve them, never write).
    cache_budget:
        Optional per-node size/TTL envelope for planner-inserted caches
        (``caching/economics.py``: a ``CacheBudget``, a dict of
        ``max_entries``/``max_bytes``/``ttl_seconds``, or a bare int
        entry budget).  Recorded in each node directory's manifest and
        enforced on ``close()`` / via ``repro cache evict``.
    optimize:
        ``"all"`` (default) runs the full pass pipeline of
        ``core/rewrite.py``; ``"none"`` executes the naive lowered
        forest; a list of pass names (drawn from
        ``repro.core.rewrite.OPTIMIZER_PASSES``) runs exactly those, in
        the given order.
    prefetch:
        Asynchronous data plane (``caching/dataplane.py``): when True
        (default), planner-inserted caches on prefetchable backends are
        stamped so the executors issue their warm-path store reads on a
        background I/O pool as soon as each node's input frame exists,
        overlapping compute; miss-path writes move to a bounded
        write-behind queue flushed on ``close()``/``drain()``.  Results
        are per-qid bit-identical with and without it (property-tested);
        gated per node by :func:`repro.core.cost.should_prefetch` and
        globally by ``REPRO_PREFETCH=0`` / ``REPRO_WRITE_BEHIND=0``.
    """

    def __init__(self, pipelines: Sequence[Transformer], *,
                 cache_dir: Optional[str] = None,
                 cache_backend: Optional[str] = None,
                 memo_factory: Optional[Callable[..., Any]] = None,
                 on_stale: str = "error",
                 cache_budget: Any = None,
                 optimize: Union[str, Sequence[str], None] = "all",
                 prefetch: bool = True):
        self.pipelines: List[Transformer] = list(pipelines)
        self.cache_dir = cache_dir
        self.cache_backend = cache_backend
        self.cache_budget = cache_budget
        self._memo_factory = memo_factory
        self.on_stale = on_stale
        self.optimize = optimize
        self.prefetch = bool(prefetch)
        passes = resolve_passes(optimize)

        # -- layer 1: lowering ---------------------------------------------
        self.graph: PlanGraph = lower(self.pipelines)
        self.nodes_total_naive = sum(plan_size(p) for p in self.pipelines)

        self._node_fps: Optional[Dict[int, str]] = None
        self._plan_manifest_path: Optional[str] = None

        # -- layer 2: optimizer (structural pre-memo passes) ---------------
        pre = [name for name in passes
               if name not in POST_MEMO_PASSES
               and name not in PLACEMENT_PASSES and name != "operand-order"]
        self.pass_stats: List[PassStats] = [
            run_pass(self.graph, name) for name in pre]
        if "cse" in pre and any(p.name == "pushdown" and p.cutoffs_pushed
                                for p in self.pass_stats):
            # pushdown can make previously distinct subtrees structurally
            # identical (e.g. `r % 3` fused next to a literal `r(n=3)`);
            # one more normalize+cse round merges them so the "any
            # identical subtree executes once" invariant holds
            self.pass_stats += [run_pass(self.graph, name)
                                for name in ("normalize", "cse")
                                if name in pre]
        # the cost-aware ordering pass runs last of the pre-memo passes,
        # after the re-round, so it orders the final structural DAG
        if "operand-order" in passes:
            self._ensure_cost_ctx()
            self.pass_stats.append(run_pass(self.graph, "operand-order"))

        if (cache_dir is not None or memo_factory is not None
                or cache_backend is not None):
            # cache placement must decide *before* memos are opened
            if "cache-place" in passes:
                self._ensure_cost_ctx()
                self.pass_stats.append(run_pass(self.graph, "cache-place"))
            self._insert_memos()
            # post-memo passes consult the freshly opened cache manifests
            # (cache-prune) and the manifest's run history (autotune)
            post = [name for name in passes if name in POST_MEMO_PASSES]
            if "autotune" in post:
                self._ensure_cost_ctx()
            self.pass_stats += [run_pass(self.graph, name) for name in post]
        self._label_nodes()
        # the self-describing record is built lazily — fingerprinting
        # every node is only worth paying for when something consumes it
        # (explain(), to_record(), or a plan manifest)
        self._record: Optional[Dict[str, Any]] = None
        if cache_dir is not None:
            self._write_plan_manifest()
        self.stats: Optional[PlanStats] = None   # last run

    # -- compatibility views ------------------------------------------------
    @property
    def source(self) -> IRNode:
        return self.graph.source

    @property
    def terminals(self) -> List[IRNode]:
        return self.graph.terminals

    @property
    def nodes(self) -> Dict[Tuple, IRNode]:
        """Key-addressed node view.  After CSE keys are unique; under
        ``optimize="none"`` duplicate subtrees collapse in this *view*
        only (the executor addresses nodes by instance)."""
        out: Dict[Tuple, IRNode] = {}
        for node in self.graph.nodes:
            out.setdefault(node.key, node)
        return out

    def _label_nodes(self) -> None:
        """Unique display labels: the same stage planned under two
        different prefixes is two nodes and must not share a
        ``node_times_s`` entry."""
        seen: Dict[str, int] = {}
        for node in self.graph.nodes:
            if node.kind == "source":
                node.label = "<source>"
                continue
            base = repr(node.stage)
            k = seen.get(base, 0)
            seen[base] = k + 1
            node.label = base if k == 0 else f"{base}#{k}"

    # -- provenance --------------------------------------------------------
    def node_fingerprints(self) -> Dict[int, str]:
        """Provenance fingerprint per plan node (id-keyed): the stage's
        transformer fingerprint folded over the fingerprints of its
        input nodes, so a config/code change anywhere upstream changes
        every downstream node's fingerprint (``caching/provenance.py``).
        Commutative combine operands fold in sorted order, so the
        fingerprints — and everything keyed on them: cache provenance,
        measured costs — are invariant under the ``operand-order``
        rewrite.  Deterministic across processes."""
        if self._node_fps is None:
            self._node_fps = compute_node_fingerprints(self.graph)
        return self._node_fps

    # -- cost layer --------------------------------------------------------
    def _ensure_cost_ctx(self) -> None:
        """Attach a :class:`~repro.core.cost.CostContext` as
        ``graph.cost`` (once): the measured-cost EWMA table and run
        history from the prior plan manifest, plus the microbenchmarked
        cache round-trip of the resolved backend when this plan will
        insert caches.  Consumed by the ``operand-order`` /
        ``cache-place`` / ``autotune`` passes."""
        if self.graph.cost is not None:
            return
        fps = self.node_fingerprints()
        record: Optional[Dict[str, Any]] = None
        history: List[Dict[str, Any]] = []
        if self.cache_dir is not None:
            from ..caching.provenance import combine_fingerprints
            plan_id = combine_fingerprints(
                "plan", *[fps[t.id] for t in self.graph.terminals])
            prior = os.path.join(self.cache_dir, "plans", f"{plan_id}.json")
            if os.path.exists(prior):
                try:
                    import json
                    with open(prior, "r", encoding="utf-8") as f:
                        record = json.load(f)
                    history = [r for r in record.get("runs", [])
                               if isinstance(r, dict)]
                except Exception:
                    record = None
        backend = round_trip = None
        if (self.cache_dir is not None or self.cache_backend is not None
                or self._memo_factory is not None):
            from ..caching.backends import (measure_round_trip,
                                            resolve_backend_name)
            try:
                # with no explicit selector each cache family picks its
                # own default, so there is no single name to promote —
                # ctx.backend stays None (cache-place still *skips* using
                # the measured round trip of a representative store)
                resolved = resolve_backend_name(self.cache_backend,
                                                default="sqlite")
                round_trip = measure_round_trip(resolved)
                if self.cache_backend is not None:
                    backend = resolved
            except Exception:
                backend = round_trip = None
        self.graph.cost = CostContext(
            model=CostModel.from_manifest(record), fps=fps,
            backend=backend, round_trip_s=round_trip, history=history)

    def tuning(self) -> Dict[str, Any]:
        """Knob values chosen by the ``autotune`` pass (``n_shards``,
        ``max_batch``, ``max_wait_ms`` — whichever had evidence), flat
        ``{knob: value}``.  ``serve`` consumes these via
        ``max_batch="auto"``; offline callers can forward ``n_shards``
        to :meth:`run`.  Empty when autotune did not run or had no
        evidence."""
        return {k: v.get("value") for k, v in self.graph.tuning.items()
                if isinstance(v, dict)}

    # -- planner-inserted memoization --------------------------------------
    def _insert_memos(self) -> None:
        factory = self._memo_factory
        if factory is None:
            from ..caching.auto import auto_cache_or_none
            factory = auto_cache_or_none
        kwargs: Dict[str, Any] = {}
        if self.cache_backend is not None:
            kwargs["backend"] = self.cache_backend
        if self.cache_budget is not None:
            kwargs["budget"] = self.cache_budget
        fps = self.node_fingerprints()
        for node in self.graph.nodes:
            if node.kind != "stage":
                continue
            if node.cache_skip:
                continue                 # cache-place: recompute is cheaper
            path = None
            if self.cache_dir is not None:
                # key the store by the node's full structural position so
                # the same stage under different prefixes never collides;
                # sha256 (not hash()) so the path is stable across
                # processes; the commutative-canonical key (when the
                # normalize pass ran) so it is stable under operand-order
                # swaps — a reorder must never cool a warm cache
                basis = node.canon_key if node.canon_key is not None \
                    else node.key
                digest = hashlib.sha256(
                    repr(basis).encode()).hexdigest()[:16]
                path = os.path.join(
                    self.cache_dir, pipeline_hash(node.stage) + "-" + digest)
            wanted = {**kwargs, "fingerprint": fps[node.id],
                      "on_stale": self.on_stale,
                      # planner-inserted caches opt into write-behind:
                      # the plan's close()/collect path drains them, and
                      # relaxing cross-process puts from exactly-once to
                      # at-least-once-with-identical-results is safe for
                      # deterministic transformers (hand-wrapped caches
                      # keep synchronous puts unless asked)
                      "async_writes": True}
            if node.backend_override is not None:
                wanted["backend"] = node.backend_override
            node.cache = factory(node.stage, path,
                                 **_accepted_kwargs(factory, wanted))
        self._stamp_prefetch()

    def _stamp_prefetch(self) -> None:
        """Mark which memoized nodes the executors should prefetch:
        plan opt-in (``prefetch=``), a global kill switch
        (``REPRO_PREFETCH=0``), the backend's ``prefetchable`` flag
        (memory-speed tiers decline), and the cost gate
        (:func:`~repro.core.cost.should_prefetch` on the measured store
        round trip).  Purely a scheduling decision — results are
        identical either way."""
        from ..caching.dataplane import prefetch_default
        if not (self.prefetch and prefetch_default()):
            return
        cost = self.graph.cost
        round_trip = cost.round_trip_s if cost is not None else None
        if not should_prefetch(round_trip):
            return
        for node in self.graph.nodes:
            cache = node.cache
            if cache is None or not getattr(cache, "prefetchable", False):
                continue
            node.prefetch = True

    # -- explain / manifests ------------------------------------------------
    def _build_record(self) -> Dict[str, Any]:
        """The plan's self-describing record: structure, provenance,
        optimizer accounting.  Written to the plan manifest and rendered
        by ``explain()`` / ``repro plan explain`` (same renderer, so the
        two round-trip)."""
        from ..caching.provenance import (PLAN_MANIFEST_VERSION,
                                          combine_fingerprints)
        fps = self.node_fingerprints()
        plan_id = combine_fingerprints(
            "plan", *[fps[t.id] for t in self.graph.terminals])
        nodes = []
        for node in self.graph.nodes:
            if node.kind == "source":
                continue                 # rendered implicitly as <source>
            cache = node.cache
            # custom memo factories may return wrappers without a .path
            cache_path = getattr(cache, "path", None)
            nodes.append({
                "id": node.id,
                "label": node.label,
                "kind": node.kind,
                "relation": node.relation,
                "fingerprint": fps[node.id],
                "dir": os.path.basename(cache_path)
                       if cache_path is not None else None,
                "family": type(cache).__name__ if cache is not None else None,
                "inputs": [i.id for i in node.inputs],
                "touched_by": list(node.touched_by),
                "inlined": node.inlined,
                "probe_input": node.probe_input.id
                               if node.probe_input is not None else None,
                "cost_est_s": _round_cost(node.cost_est_s)
                              if node.cost_est_s is not None else None,
                "cost_src": node.cost_src,
                "cache_skip": node.cache_skip,
            })
        agg = self._aggregate_pass_stats()
        return {
            "format_version": PLAN_MANIFEST_VERSION,
            "plan_id": plan_id,
            "created_at": time.time(),
            "pipelines": [repr(p) for p in self.pipelines],
            "cache_backend": self.cache_backend,
            "on_stale": self.on_stale,
            "terminals": [t.id for t in self.graph.terminals],
            "nodes": nodes,
            "optimizer": {
                "passes": [p.name for p in self.pass_stats],
                "nodes_eliminated": agg["nodes_eliminated"],
                "cutoffs_pushed": agg["cutoffs_pushed"],
                "nodes_marked_prunable": agg["nodes_marked_prunable"],
                "caches_skipped": agg["caches_skipped"],
                "caches_promoted": agg["caches_promoted"],
                "inputs_reordered": agg["inputs_reordered"],
                "pass_stats": [p.as_dict() for p in self.pass_stats],
            },
            "tuning": dict(self.graph.tuning),
            "runs": [],
        }

    def _aggregate_pass_stats(self) -> Dict[str, int]:
        return {
            "nodes_eliminated": sum(p.nodes_eliminated
                                    for p in self.pass_stats),
            "cutoffs_pushed": sum(p.cutoffs_pushed for p in self.pass_stats),
            "nodes_marked_prunable": sum(p.nodes_marked_prunable
                                         for p in self.pass_stats),
            "caches_skipped": sum(p.caches_skipped for p in self.pass_stats),
            "caches_promoted": sum(p.caches_promoted
                                   for p in self.pass_stats),
            "inputs_reordered": sum(p.inputs_reordered
                                    for p in self.pass_stats),
        }

    def explain(self) -> str:
        """ASCII rendering of the optimized plan: one tree per pipeline
        with per-node id, relation, provenance fingerprint, cache family,
        the optimizer passes that touched the node and — when the cost
        layer ran — estimated-vs-actual per-query cost columns
        (``cost[est=… act=… src=…]``).  Byte-identical to ``repro plan
        explain`` over this plan's manifest: actuals come from the same
        persisted EWMA table the CLI reads."""
        record = self.to_record()
        if self._plan_manifest_path is None and self.stats is not None \
                and self.stats.node_times_s:
            # no manifest to carry measured costs (in-memory plan):
            # overlay this run's actuals so explain() still shows them
            import copy
            record = copy.deepcopy(record)
            fold_costs(record, self.stats)
        return render_explain(record)

    def to_record(self) -> Dict[str, Any]:
        """The plan-manifest record (see ``_build_record``), built on
        first use."""
        if self._record is None:
            self._record = self._build_record()
        return self._record

    def _write_plan_manifest(self) -> None:
        """Record this plan in ``<cache_dir>/plans/<plan_id>.json`` so the
        cache directory is self-describing: which pipelines used it,
        which node dirs belong to which DAG position, with what
        provenance.  ``repro cache ls / gc --orphaned`` and
        ``repro plan explain`` consume this."""
        from ..caching.provenance import save_plan_manifest
        record = self.to_record()
        # re-planning the same pipeline set keeps its recorded history
        prior = os.path.join(self.cache_dir, "plans",
                             f"{record['plan_id']}.json")
        if os.path.exists(prior):
            try:
                import json
                with open(prior, "r", encoding="utf-8") as f:
                    old = json.load(f)
                record["created_at"] = old.get("created_at",
                                               record["created_at"])
                record["runs"] = list(old.get("runs", []))
                # measured per-node costs survive re-planning: they are
                # fingerprint-keyed, so stale entries simply never match
                record["costs"] = dict(old.get("costs") or {})
                annotate_node_actuals(record)
            except Exception:
                pass
        self._plan_manifest_path = save_plan_manifest(self.cache_dir, record)

    def _record_run(self, stats: PlanStats) -> None:
        """Append one run record to the plan manifest (best-effort)."""
        if self._plan_manifest_path is None:
            return
        try:
            import json
            with open(self._plan_manifest_path, "r", encoding="utf-8") as f:
                record = json.load(f)
            runs = record.setdefault("runs", [])
            run: Dict[str, Any] = {
                "at": time.time(),
                "nodes_executed": stats.nodes_executed,
                "nodes_pruned": stats.nodes_pruned,
                "cache_hits": stats.cache_hits,
                "cache_misses": stats.cache_misses,
                "cache_prefetched": stats.cache_prefetched,
                "n_shards": stats.n_shards,
                "n_workers": stats.n_workers,
                "wall_time_s": round(stats.wall_time_s, 4),
                "n_queries": stats.n_queries,
            }
            online = stats.online or {}
            if online:
                run["online"] = {k: online[k] for k in (
                    "batch_occupancy", "queue_depth_p50", "queue_depth_p99",
                    "max_batch", "max_wait_ms") if k in online}
            runs.append(run)
            del runs[:-50]               # keep the tail bounded
            # fold this run's measured per-node times into the
            # fingerprint-keyed EWMA cost table (core/cost.py) — the
            # next compile's cost model reads it back
            fold_costs(record, stats)
            if self._record is not None:
                # keep the in-memory record (explain()) in sync with the
                # persisted EWMA so both render identical actual columns
                self._record["costs"] = record.get("costs", {})
                annotate_node_actuals(self._record)
            from ..caching.backends import atomic_write_bytes
            atomic_write_bytes(
                self._plan_manifest_path,
                json.dumps(record, indent=2, sort_keys=True).encode("utf-8"))
        except Exception:
            pass

    def close(self) -> None:
        """Close planner-inserted caches (flushes temporary stores and
        write-behind queues)."""
        for node in self.graph.nodes:
            if node.cache is not None and hasattr(node.cache, "close"):
                node.cache.close()

    def drain(self) -> None:
        """Make planner-inserted caches durable without closing them:
        flush each family's write-behind queue and access log
        (``caching/dataplane.py``).  A crash after ``drain()`` returns
        loses nothing; a crash before it recomputes pending entries."""
        for node in self.graph.nodes:
            if node.cache is not None and hasattr(node.cache, "drain"):
                node.cache.drain()

    def __enter__(self) -> "ExecutionPlan":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False

    # -- analysis ----------------------------------------------------------
    def n_nodes(self) -> int:
        return self.graph.n_nodes()

    # -- execution ---------------------------------------------------------
    def run(self, queries: Any, *, batch_size: Optional[int] = None,
            n_shards: Optional[int] = None,
            max_workers: Optional[int] = None,
            ) -> Tuple[List[ColFrame], PlanStats]:
        """Execute the DAG once over ``queries``.

        Every node runs at most once per shard; results are identical to
        naive per-pipeline execution (the cache-transparency invariant,
        asserted in tests/test_plan.py and tests/test_rewrite.py).

        ``n_shards`` / ``max_workers`` enable the concurrent executor:
        the query frame is partitioned into qid-aligned shards and
        (node, shard) tasks are scheduled in topological wavefronts on a
        thread pool.  With ``max_workers > 1`` and ``n_shards`` unset,
        the shard count defaults to ``ceil(len(queries)/batch_size)``
        when ``batch_size`` is given, else to ``max_workers``.  The
        default (both unset) is the sequential executor.

        Sharding assumes stages are row-local per qid (a qid group's
        output depends only on that group's rows) — the same contract
        ``batch_size`` already imposes.  Stages computing cross-query
        statistics must declare ``shardable=False``; the executor then
        falls back to one shard (branch parallelism still applies).
        """
        t0 = time.perf_counter()
        frame = ColFrame.coerce(queries)
        shards = resolve_n_shards(self.graph, frame, batch_size, n_shards,
                                  max_workers)
        if max_workers is not None:
            workers = max(1, int(max_workers))
        else:
            workers = min(32, shards) if shards > 1 else 1
        cache_base = self._cache_counters()
        compute_base = self._compute_counters()
        stats = self._new_stats()
        stats.n_queries = len(frame)
        rec = _Recorder()
        if shards <= 1 and workers <= 1:
            outs = run_sequential(self.graph, frame, batch_size, rec)
        else:
            outs, bounds = run_concurrent(self.graph, frame, batch_size,
                                          shards, workers, rec)
            stats.n_shards = len(bounds)
            stats.n_workers = workers
        self._fill_exec_stats(stats, rec)
        self._fill_compute_stats(stats, compute_base)
        self._finalize_stats(stats, cache_base, t0)
        if stats.n_shards > 1 or stats.n_workers > 1:
            busy = sum(b - a for _, _, a, b in rec.records)
            stats.occupancy = busy / (workers * stats.wall_time_s) \
                if stats.wall_time_s > 0 else 0.0
        return outs, stats

    def warm(self, queries: Any, *, batch_size: Optional[int] = None,
             chunk_rows: Optional[int] = None) -> PlanStats:
        """Speculative precomputation: execute the DAG over ``queries``
        purely to populate the planner-inserted caches, discarding the
        outputs (the paper's precomputation idea as an offline tool —
        `repro cache warm` drives this).

        The query frame is processed in qid-aligned chunks of at most
        ``chunk_rows`` rows (default: one chunk), so arbitrarily large
        warming logs run in bounded memory; chunking reuses the offline
        scheduler's shard machinery, so results in the caches are
        identical to a single full run.  Returns the usual
        :class:`PlanStats` (``cache_misses`` counts entries actually
        precomputed; a second warm over the same frame is all hits).
        """
        t0 = time.perf_counter()
        frame = ColFrame.coerce(queries)
        cache_base = self._cache_counters()
        compute_base = self._compute_counters()
        stats = self._new_stats()
        stats.n_queries = len(frame)
        rec = _Recorder()
        run_warm(self.graph, frame, batch_size, chunk_rows=chunk_rows,
                 rec=rec)
        self._fill_exec_stats(stats, rec)
        self._fill_compute_stats(stats, compute_base)
        self._finalize_stats(stats, cache_base, t0)
        return stats

    def _new_stats(self) -> PlanStats:
        agg = self._aggregate_pass_stats()
        return PlanStats(
            prefix_len=len(longest_common_prefix(self.pipelines)),
            n_pipelines=len(self.pipelines),
            nodes_total=self.nodes_total_naive,
            nodes_planned=self.n_nodes(),
            optimizer_passes=[p.name for p in self.pass_stats],
            nodes_eliminated=agg["nodes_eliminated"],
            cutoffs_pushed=agg["cutoffs_pushed"],
            pass_times_s=self._pass_times())

    def _pass_times(self) -> Dict[str, float]:
        """Per-pass wall time, summed over repeated rounds of a pass."""
        times: Dict[str, float] = {}
        for p in self.pass_stats:
            times[p.name] = round(times.get(p.name, 0.0) + p.time_s, 6)
        return times

    def _fill_exec_stats(self, stats: PlanStats, rec: _Recorder) -> None:
        executed = set()
        for label, s, a, b in rec.records:
            executed.add(label)
            stats.node_times_s[label] = \
                stats.node_times_s.get(label, 0.0) + (b - a)
            stats.node_exec_counts[label] = \
                stats.node_exec_counts.get(label, 0) + 1
        stats.nodes_executed = len(executed)
        # deferred (cache-prune) nodes whose chain never ran this run
        stats.nodes_pruned = sum(
            1 for n in self.graph.nodes
            if n.inlined and n.label not in executed)
        if stats.n_shards > 1:
            for s in range(stats.n_shards):
                spans = [(a, b) for _, sh, a, b in rec.records if sh == s]
                stats.shard_times_s.append(
                    max(b for _, b in spans) - min(a for a, _ in spans)
                    if spans else 0.0)

    def _finalize_stats(self, stats: PlanStats,
                        cache_base: Tuple[int, int, int], t0: float) -> None:
        stats.stage_invocations_saved = \
            stats.nodes_total - stats.nodes_executed
        hits, misses, prefetched = self._cache_counters()
        stats.cache_hits = hits - cache_base[0]
        stats.cache_misses = misses - cache_base[1]
        stats.cache_prefetched = prefetched - cache_base[2]
        stats.wall_time_s = time.perf_counter() - t0
        if stats.n_shards > 1 and stats.wall_time_s > 0 \
                and stats.shard_times_s \
                and stats.speedup_vs_sequential is None:
            # sum of per-shard busy spans ≈ the sequential wall this run
            # would have taken; benchmarks overwrite with a measured ratio
            stats.speedup_vs_sequential = round(
                sum(stats.shard_times_s) / stats.wall_time_s, 2)
        self.stats = stats
        self._record_run(stats)

    def _cache_counters(self) -> Tuple[int, int, int]:
        hits = misses = prefetched = 0
        for node in self.graph.nodes:
            cs = getattr(node.cache, "stats", None)
            if cs is not None:
                hits += cs.hits
                misses += cs.misses
                prefetched += getattr(cs, "prefetched", 0)
        return hits, misses, prefetched

    def _compute_counters(self) -> Dict[str, Tuple[float, int]]:
        """Cumulative raw-compute counters per *cached* node label (see
        ``CacheStats.compute_s``) — snapshot before a run, delta after."""
        out: Dict[str, Tuple[float, int]] = {}
        for node in self.graph.nodes:
            cs = getattr(node.cache, "stats", None)
            if cs is not None and node.label is not None:
                out[node.label] = (float(getattr(cs, "compute_s", 0.0)),
                                   int(getattr(cs, "compute_queries", 0)))
        return out

    def _fill_compute_stats(self, stats: PlanStats,
                            base: Dict[str, Tuple[float, int]]) -> None:
        for label, (s1, q1) in self._compute_counters().items():
            s0, q0 = base.get(label, (0.0, 0))
            stats.node_compute_s[label] = s1 - s0
            stats.node_compute_queries[label] = q1 - q0
