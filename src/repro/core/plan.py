"""Unified experiment planner: pipelines lowered into one shared DAG.

The paper develops two complementary directions: *implicit* prefix
sharing inside ``Experiment`` (§3 — the LCP of Eq. 2, generalized to a
prefix trie for the §6 ablation limitation) and *explicit* operation
caches applied by hand (§4).  ``ExecutionPlan`` unifies both behind a
single abstraction, following the "Trie-based Experiment Plans"
follow-up (PAPERS.md): a set of pipelines is **lowered** into one DAG
whose nodes are deduplicated by structural signature, then executed
with each node run exactly once.

Improvements over the stage-list trie of ``precompute.py``:

* **Sharing through operator nodes** (§6 limitation, resolved): the
  planner recurses into binary operators (``LinearCombine``,
  ``FeatureUnion``, ``SetUnion``, ``SetIntersection``, ``Concatenate``)
  and ``ScalarProduct``, so a retriever shared under ``a + b`` and
  ``a ** c`` executes once.  ``stages_of`` treats those nodes as opaque
  and re-executes ``a`` per pipeline.
* **Planner-inserted memoization** (§4 + §6 future work): with a
  ``cache_dir``, every node whose transformer declares sufficient
  ``auto_cache`` metadata gets the matching explicit cache family
  (KeyValueCache / ScorerCache / RetrieverCache) wrapped around it by
  the planner — researchers no longer hand-wrap stages (§4's usability
  caveat).  ``cache_backend`` selects the storage backend
  (``caching/backends.py``); a custom ``memo_factory`` makes the whole
  policy pluggable.
* **Concurrent sharded execution**: once sharing is explicit in a plan,
  the plan is also the natural unit of parallel scheduling (the
  trie-based-plans observation).  ``run(..., n_shards=S,
  max_workers=W)`` partitions the query frame into ``S`` qid-aligned
  shards and executes the DAG in topological wavefronts on a thread
  pool: independent branches (both sides of a ``combine``, sibling
  rerankers over one retrieval) and independent shards run
  concurrently; per-shard outputs merge back in shard order, so results
  match sequential execution row-set-for-row-set with identical
  scores/ranks (the cache-transparency invariant, property-tested in
  ``tests/test_plan.py``).
* **Plan-level accounting**: ``PlanStats`` extends ``PrecomputeStats``
  with planned/executed node counts, cache hit/miss totals, per-node
  wall times and — under concurrency — per-shard wall times and
  scheduler occupancy, surfaced through ``Experiment`` results and
  ``benchmarks/plan_bench.py``.

``run_with_precompute``, ``run_with_trie`` and ``Experiment`` are thin
wrappers over this module — the planner is the single execution path.
"""
from __future__ import annotations

import hashlib
import inspect
import os
import threading
import time
from collections import deque
from concurrent.futures import FIRST_COMPLETED, ThreadPoolExecutor, wait
from dataclasses import dataclass, field
from typing import (Any, Callable, Dict, List, Optional, Sequence, Tuple)

import numpy as np

from .frame import ColFrame
from .pipeline import (Compose, ScalarProduct, Transformer, _Binary,
                       pipeline_hash)
from .precompute import (PrecomputeStats, _run_stage, longest_common_prefix)

__all__ = ["ExecutionPlan", "PlanNode", "PlanStats", "plan_size"]


@dataclass
class PlanStats(PrecomputeStats):
    """Per-run accounting of a plan execution."""
    nodes_planned: int = 0               # unique DAG nodes (excl. source)
    cache_hits: int = 0                  # memo hits across inserted caches
    cache_misses: int = 0
    node_times_s: Dict[str, float] = field(default_factory=dict)
    wall_time_s: float = 0.0
    # -- concurrent executor -------------------------------------------------
    n_shards: int = 1                    # query-frame partitions executed
    n_workers: int = 1                   # thread-pool size
    shard_times_s: List[float] = field(default_factory=list)
    occupancy: float = 0.0               # busy-time / (workers × wall)
    speedup_vs_sequential: Optional[float] = None  # filled by benchmarks

    def __str__(self) -> str:
        extra = ""
        if self.n_shards > 1 or self.n_workers > 1:
            extra = (f" shards={self.n_shards} workers={self.n_workers} "
                     f"occupancy={self.occupancy:.2f}")
        return (f"PlanStats(planned={self.nodes_planned} "
                f"executed={self.nodes_executed} "
                f"naive={self.nodes_total} "
                f"saved={self.stage_invocations_saved} "
                f"cache_hits={self.cache_hits} "
                f"wall={self.wall_time_s:.3f}s{extra})")


@dataclass
class PlanNode:
    """One deduplicated unit of work in the DAG."""
    key: Tuple                           # canonical structural key
    kind: str                            # "source" | "stage" | "combine" | "scale"
    stage: Optional[Transformer]         # operator instance (None for source)
    inputs: List["PlanNode"] = field(default_factory=list)
    cache: Optional[Transformer] = None  # planner-inserted memo wrapper
    label: str = ""                      # unique display label (see _label_nodes)


def plan_size(expr: Transformer) -> int:
    """Stage invocations of one *naive* execution of ``expr`` (binary
    operators expand into 1 + both children, unlike ``stages_of``)."""
    if isinstance(expr, Compose):
        return sum(plan_size(s) for s in expr.stages)
    if isinstance(expr, _Binary):
        return 1 + plan_size(expr.left) + plan_size(expr.right)
    if isinstance(expr, ScalarProduct):
        return 1 + plan_size(expr.inner)
    return 1


def _accepted_kwargs(factory: Callable[..., Any],
                     wanted: Dict[str, Any]) -> Dict[str, Any]:
    """The subset of ``wanted`` that ``factory`` can accept — custom
    memo factories keep their minimal ``(stage, path)`` signature while
    richer ones opt into ``backend`` / ``fingerprint`` / ``on_stale``."""
    try:
        params = inspect.signature(factory).parameters.values()
    except (TypeError, ValueError):      # builtins / C callables
        return {}
    if any(p.kind == p.VAR_KEYWORD for p in params):
        return dict(wanted)
    names = {p.name for p in params
             if p.kind in (p.POSITIONAL_OR_KEYWORD, p.KEYWORD_ONLY)}
    return {k: v for k, v in wanted.items() if k in names}


def _qid_runs_unique(qids: np.ndarray) -> bool:
    """True when every qid forms one contiguous run — the property that
    makes cutting at run boundaries preserve per-qid semantics."""
    n = len(qids)
    if n == 0:
        return True
    arr = qids
    if arr.dtype == object or arr.dtype.kind in ("U", "S"):
        arr = arr.astype(str)
    change = np.empty(n, dtype=bool)
    change[0] = True
    change[1:] = arr[1:] != arr[:-1]
    return int(change.sum()) == len(np.unique(arr))


def _shard_bounds(frame: ColFrame, n_shards: int) -> List[Tuple[int, int]]:
    """Partition ``frame`` into ≤ ``n_shards`` contiguous row ranges,
    cutting only at qid-run boundaries so no query straddles a shard."""
    n = len(frame)
    if n == 0 or n_shards <= 1:
        return [(0, n)]
    if "qid" in frame:
        q = frame["qid"]
        arr = q.astype(str) if q.dtype == object or q.dtype.kind in ("U", "S") \
            else q
        cuts = np.nonzero(arr[1:] != arr[:-1])[0] + 1
    else:
        cuts = np.arange(1, n)
    sel: List[int] = []
    prev = 0
    for i in range(1, n_shards):
        target = round(i * n / n_shards)
        j = int(np.searchsorted(cuts, max(target, prev + 1)))
        cands = []
        if j < len(cuts):
            cands.append(int(cuts[j]))
        if j > 0 and int(cuts[j - 1]) > prev:
            cands.append(int(cuts[j - 1]))
        if not cands:
            continue
        c = min(cands, key=lambda x: abs(x - target))
        if prev < c < n:
            sel.append(c)
            prev = c
    bounds = [0] + sel + [n]
    return list(zip(bounds[:-1], bounds[1:]))


class ExecutionPlan:
    """Lower a pipeline set into a shared DAG and execute it.

    Parameters
    ----------
    pipelines:
        The systems of an experiment (operator-algebra expressions).
    cache_dir:
        When given, enables planner-inserted memoization: each eligible
        node gets an explicit cache (selected by ``auto_cache`` from the
        node's metadata) rooted under this directory, so repeated runs —
        or overlapping plans pointed at the same directory — hit.
    cache_backend:
        Storage backend for planner-inserted caches, by registry name
        (``"memory"`` / ``"pickle"`` / ``"dbm"`` / ``"sqlite"`` — see
        ``caching/backends.py``).  ``None`` keeps each cache family's
        default.  ``cache_backend="memory"`` alone (no ``cache_dir``)
        enables purely in-process memoization.
    memo_factory:
        Pluggable cache policy ``(transformer, path, **kw) -> wrapper |
        None``.  Defaults to ``repro.caching.auto.auto_cache_or_none``
        with uncacheable stages (per §5, e.g. DuoT5-style scorers) left
        bare.  Factories that accept them also receive ``fingerprint=``
        (the node's provenance fingerprint) and ``on_stale=``.
    on_stale:
        Policy when a node's cache directory records a different
        provenance fingerprint (``caching/provenance.py``): ``"error"``
        (default — raise ``StaleCacheError``), ``"recompute"`` (discard
        the stale entries) or ``"readonly"`` (serve them, never write).
    """

    def __init__(self, pipelines: Sequence[Transformer], *,
                 cache_dir: Optional[str] = None,
                 cache_backend: Optional[str] = None,
                 memo_factory: Optional[Callable[..., Any]] = None,
                 on_stale: str = "error"):
        self.pipelines: List[Transformer] = list(pipelines)
        self.cache_dir = cache_dir
        self.cache_backend = cache_backend
        self._memo_factory = memo_factory
        self.on_stale = on_stale
        self.source = PlanNode(key=("source",), kind="source", stage=None)
        self.nodes: Dict[Tuple, PlanNode] = {self.source.key: self.source}
        self.terminals: List[PlanNode] = [
            self._lower(p, self.source) for p in self.pipelines]
        self.nodes_total_naive = sum(plan_size(p) for p in self.pipelines)
        self._all_shardable = all(
            getattr(n.stage, "shardable", True)
            for n in self.nodes.values() if n.kind == "stage")
        self._label_nodes()
        self._node_fps: Optional[Dict[Tuple, str]] = None
        self._plan_manifest_path: Optional[str] = None
        if (cache_dir is not None or memo_factory is not None
                or cache_backend is not None):
            self._insert_memos()
        if cache_dir is not None:
            self._write_plan_manifest()
        self.stats: Optional[PlanStats] = None   # last run

    def _label_nodes(self) -> None:
        """Unique display labels: the same stage planned under two
        different prefixes is two nodes and must not share a
        ``node_times_s`` entry."""
        seen: Dict[str, int] = {}
        for node in self.nodes.values():
            if node.kind == "source":
                node.label = "<source>"
                continue
            base = repr(node.stage)
            k = seen.get(base, 0)
            seen[base] = k + 1
            node.label = base if k == 0 else f"{base}#{k}"

    # -- lowering ----------------------------------------------------------
    def _node(self, key: Tuple, kind: str, stage: Transformer,
              inputs: List[PlanNode]) -> PlanNode:
        node = self.nodes.get(key)
        if node is None:
            node = PlanNode(key=key, kind=kind, stage=stage, inputs=inputs)
            self.nodes[key] = node
        return node

    def _lower(self, expr: Transformer, inp: PlanNode) -> PlanNode:
        """Recursively lower ``expr`` applied to ``inp``'s result."""
        if isinstance(expr, Compose):
            node = inp
            for stage in expr.stages:
                node = self._lower(stage, node)
            return node
        if isinstance(expr, _Binary):
            left = self._lower(expr.left, inp)
            right = self._lower(expr.right, inp)
            key = ("combine", type(expr).__name__, left.key, right.key)
            return self._node(key, "combine", expr, [left, right])
        if isinstance(expr, ScalarProduct):
            inner = self._lower(expr.inner, inp)
            key = ("scale", expr.scalar, inner.key)
            return self._node(key, "scale", expr, [inner])
        key = ("stage", expr.signature(), inp.key)
        return self._node(key, "stage", expr, [inp])

    # -- provenance --------------------------------------------------------
    def node_fingerprints(self) -> Dict[Tuple, str]:
        """Provenance fingerprint per plan node: the stage's transformer
        fingerprint folded over the fingerprints of its input nodes, so
        a config/code change anywhere upstream changes every downstream
        node's fingerprint (``caching/provenance.py``).  Deterministic
        across processes."""
        if self._node_fps is None:
            from ..caching.auto import derive_fingerprint
            from ..caching.provenance import combine_fingerprints
            fps: Dict[Tuple, str] = {
                self.source.key: combine_fingerprints("plan-source")}
            # self.nodes preserves insertion order, and _lower creates
            # every input before its consumer — already topological
            for node in self.nodes.values():
                if node.kind == "source":
                    continue
                stage_fp = derive_fingerprint(node.stage) \
                    or combine_fingerprints("sig", repr(node.stage))
                fps[node.key] = combine_fingerprints(
                    "node", node.kind, stage_fp,
                    *[fps[i.key] for i in node.inputs])
            self._node_fps = fps
        return self._node_fps

    # -- planner-inserted memoization --------------------------------------
    def _insert_memos(self) -> None:
        factory = self._memo_factory
        if factory is None:
            from ..caching.auto import auto_cache_or_none
            factory = auto_cache_or_none
        kwargs: Dict[str, Any] = {}
        if self.cache_backend is not None:
            kwargs["backend"] = self.cache_backend
        fps = self.node_fingerprints()
        for node in self.nodes.values():
            if node.kind != "stage":
                continue
            path = None
            if self.cache_dir is not None:
                # key the store by the node's full structural position so
                # the same stage under different prefixes never collides;
                # sha256 (not hash()) so the path is stable across processes
                digest = hashlib.sha256(
                    repr(node.key).encode()).hexdigest()[:16]
                path = os.path.join(
                    self.cache_dir, pipeline_hash(node.stage) + "-" + digest)
            node.cache = factory(node.stage, path, **_accepted_kwargs(
                factory, {**kwargs, "fingerprint": fps[node.key],
                          "on_stale": self.on_stale}))

    def _write_plan_manifest(self) -> None:
        """Record this plan in ``<cache_dir>/plans/<plan_id>.json`` so the
        cache directory is self-describing: which pipelines used it,
        which node dirs belong to which DAG position, with what
        provenance.  ``repro cache ls / gc --orphaned`` consume this."""
        from ..caching.provenance import (PLAN_MANIFEST_VERSION,
                                          combine_fingerprints,
                                          save_plan_manifest)
        fps = self.node_fingerprints()
        plan_id = combine_fingerprints(
            "plan", *[fps[t.key] for t in self.terminals])
        nodes = []
        for node in self.nodes.values():
            if node.kind == "source":
                continue
            cache = node.cache
            # custom memo factories may return wrappers without a .path
            cache_path = getattr(cache, "path", None)
            nodes.append({
                "label": node.label,
                "kind": node.kind,
                "fingerprint": fps[node.key],
                "dir": os.path.basename(cache_path)
                       if cache_path is not None else None,
                "family": type(cache).__name__ if cache is not None else None,
                "inputs": [i.label for i in node.inputs],
            })
        record = {
            "format_version": PLAN_MANIFEST_VERSION,
            "plan_id": plan_id,
            "created_at": time.time(),
            "pipelines": [repr(p) for p in self.pipelines],
            "cache_backend": self.cache_backend,
            "on_stale": self.on_stale,
            "nodes": nodes,
            "runs": [],
        }
        # re-planning the same pipeline set keeps its recorded history
        prior = os.path.join(self.cache_dir, "plans", f"{plan_id}.json")
        if os.path.exists(prior):
            try:
                import json
                with open(prior, "r", encoding="utf-8") as f:
                    old = json.load(f)
                record["created_at"] = old.get("created_at",
                                               record["created_at"])
                record["runs"] = list(old.get("runs", []))
            except Exception:
                pass
        self._plan_manifest_path = save_plan_manifest(self.cache_dir, record)

    def _record_run(self, stats: PlanStats) -> None:
        """Append one run record to the plan manifest (best-effort)."""
        if self._plan_manifest_path is None:
            return
        try:
            import json
            with open(self._plan_manifest_path, "r", encoding="utf-8") as f:
                record = json.load(f)
            runs = record.setdefault("runs", [])
            runs.append({
                "at": time.time(),
                "nodes_executed": stats.nodes_executed,
                "cache_hits": stats.cache_hits,
                "cache_misses": stats.cache_misses,
                "n_shards": stats.n_shards,
                "n_workers": stats.n_workers,
                "wall_time_s": round(stats.wall_time_s, 4),
            })
            del runs[:-50]               # keep the tail bounded
            from ..caching.backends import atomic_write_bytes
            atomic_write_bytes(
                self._plan_manifest_path,
                json.dumps(record, indent=2, sort_keys=True).encode("utf-8"))
        except Exception:
            pass

    def close(self) -> None:
        """Close planner-inserted caches (flushes temporary stores)."""
        for node in self.nodes.values():
            if node.cache is not None and hasattr(node.cache, "close"):
                node.cache.close()

    def __enter__(self) -> "ExecutionPlan":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False

    # -- analysis ----------------------------------------------------------
    def n_nodes(self) -> int:
        return len(self.nodes) - 1       # exclude the source

    # -- execution ---------------------------------------------------------
    def run(self, queries: Any, *, batch_size: Optional[int] = None,
            n_shards: Optional[int] = None,
            max_workers: Optional[int] = None,
            ) -> Tuple[List[ColFrame], PlanStats]:
        """Execute the DAG once over ``queries``.

        Every node runs at most once per shard; results are identical to
        naive per-pipeline execution (the cache-transparency invariant,
        asserted in tests/test_plan.py).

        ``n_shards`` / ``max_workers`` enable the concurrent executor:
        the query frame is partitioned into qid-aligned shards and
        (node, shard) tasks are scheduled in topological wavefronts on a
        thread pool.  With ``max_workers > 1`` and ``n_shards`` unset,
        the shard count defaults to ``ceil(len(queries)/batch_size)``
        when ``batch_size`` is given, else to ``max_workers``.  The
        default (both unset) is the sequential executor.

        Sharding assumes stages are row-local per qid (a qid group's
        output depends only on that group's rows) — the same contract
        ``batch_size`` already imposes.  Stages computing cross-query
        statistics must declare ``shardable=False``; the executor then
        falls back to one shard (branch parallelism still applies).
        """
        t0 = time.perf_counter()
        frame = ColFrame.coerce(queries)
        shards = self._resolve_n_shards(frame, batch_size, n_shards,
                                        max_workers)
        if max_workers is not None:
            workers = max(1, int(max_workers))
        else:
            workers = min(32, shards) if shards > 1 else 1
        if shards <= 1 and workers <= 1:
            return self._run_sequential(frame, batch_size, t0)
        return self._run_concurrent(frame, batch_size, shards, workers, t0)

    def _new_stats(self) -> PlanStats:
        return PlanStats(
            prefix_len=len(longest_common_prefix(self.pipelines)),
            n_pipelines=len(self.pipelines),
            nodes_total=self.nodes_total_naive,
            nodes_planned=self.n_nodes())

    def _resolve_n_shards(self, frame: ColFrame,
                          batch_size: Optional[int],
                          n_shards: Optional[int],
                          max_workers: Optional[int]) -> int:
        n = len(frame)
        if n == 0:
            return 1
        if n_shards is not None:
            want = int(n_shards)
        elif max_workers is not None and int(max_workers) > 1:
            want = -(-n // int(batch_size)) if batch_size else int(max_workers)
        else:
            return 1
        want = max(1, min(want, n))
        if want > 1 and not self._all_shardable:
            # a stage declared shardable=False (cross-query statistics);
            # partitioning the frame would change its results.  Keep one
            # shard (branch-level parallelism via max_workers still
            # applies).
            return 1
        if want > 1 and "qid" in frame \
                and not _qid_runs_unique(frame["qid"]):
            # a qid with non-contiguous rows cannot be cut without
            # splitting its group; keep one shard
            return 1
        return want

    def _exec_node(self, node: PlanNode, ins: List[ColFrame],
                   batch_size: Optional[int]) -> ColFrame:
        if node.kind == "stage":
            runner = node.cache if node.cache is not None else node.stage
            if not getattr(node.stage, "shardable", True):
                # batching partitions the frame exactly like sharding
                # would — a cross-query stage must see it whole
                return runner(ins[0])
            return _run_stage(runner, ins[0], batch_size)
        if node.kind == "scale":
            return node.stage.apply(ins[0])
        return node.stage.combine(ins[0], ins[1])          # combine

    def _run_sequential(self, frame: ColFrame, batch_size: Optional[int],
                        t0: float) -> Tuple[List[ColFrame], PlanStats]:
        cache_base = self._cache_counters()
        results: Dict[Tuple, ColFrame] = {self.source.key: frame}
        stats = self._new_stats()

        def evaluate(node: PlanNode) -> ColFrame:
            memo = results.get(node.key)
            if memo is not None:
                return memo
            ins = [evaluate(i) for i in node.inputs]
            t1 = time.perf_counter()
            out = self._exec_node(node, ins, batch_size)
            stats.nodes_executed += 1
            stats.node_times_s[node.label] = \
                stats.node_times_s.get(node.label, 0.0) + \
                (time.perf_counter() - t1)
            results[node.key] = out
            return out

        outs = [evaluate(t) for t in self.terminals]
        self._finalize_stats(stats, cache_base, t0)
        return outs, stats

    def _run_concurrent(self, frame: ColFrame, batch_size: Optional[int],
                        n_shards: int, workers: int, t0: float,
                        ) -> Tuple[List[ColFrame], PlanStats]:
        """Sharded wavefront execution on a thread pool.

        Each (node, shard) pair is one task; a task becomes ready when
        its node's inputs have completed *for its shard*, so wavefronts
        advance independently per shard and independent branches of one
        shard run in parallel.  Python-level work holds the GIL, but IR
        stages dominated by I/O, BLAS or accelerator dispatch release
        it — those are exactly the stages worth sharding.
        """
        cache_base = self._cache_counters()
        stats = self._new_stats()
        bounds = _shard_bounds(frame, n_shards)
        n_shards = len(bounds)
        stats.n_shards = n_shards
        stats.n_workers = workers

        results: Dict[Tuple[Tuple, int], ColFrame] = {}
        for s, (lo, hi) in enumerate(bounds):
            results[(self.source.key, s)] = frame.take(np.arange(lo, hi))

        children: Dict[Tuple, List[PlanNode]] = {}
        indeg: Dict[Tuple[Tuple, int], int] = {}
        for node in self.nodes.values():
            if node.kind == "source":
                continue
            for inp in node.inputs:
                children.setdefault(inp.key, []).append(node)
            for s in range(n_shards):
                indeg[(node.key, s)] = len(node.inputs)

        ready: deque = deque()

        def complete(key: Tuple, s: int) -> None:
            for child in children.get(key, ()):
                k = (child.key, s)
                indeg[k] -= 1
                if indeg[k] == 0:
                    ready.append((child, s))

        for s in range(n_shards):
            complete(self.source.key, s)

        records: List[Tuple[str, int, float, float]] = []
        rec_lock = threading.Lock()

        def exec_task(node: PlanNode, s: int) -> None:
            ins = [results[(i.key, s)] for i in node.inputs]
            t1 = time.perf_counter()
            out = self._exec_node(node, ins, batch_size)
            t2 = time.perf_counter()
            results[(node.key, s)] = out
            with rec_lock:
                records.append((node.label, s, t1, t2))

        with ThreadPoolExecutor(max_workers=workers) as pool:
            futures: Dict[Any, Tuple[PlanNode, int]] = {}

            def submit_ready() -> None:
                while ready:
                    node, s = ready.popleft()
                    fut = pool.submit(exec_task, node, s)
                    futures[fut] = (node, s)

            submit_ready()
            while futures:
                done, _ = wait(set(futures), return_when=FIRST_COMPLETED)
                for fut in done:
                    node, s = futures.pop(fut)
                    fut.result()                 # propagate task errors
                    complete(node.key, s)
                submit_ready()

        outs = [ColFrame.concat([results[(t.key, s)]
                                 for s in range(n_shards)])
                for t in self.terminals]

        executed = set()
        for label, s, a, b in records:
            executed.add(label)
            stats.node_times_s[label] = \
                stats.node_times_s.get(label, 0.0) + (b - a)
        stats.nodes_executed = len(executed)
        for s in range(n_shards):
            spans = [(a, b) for _, sh, a, b in records if sh == s]
            stats.shard_times_s.append(
                max(b for _, b in spans) - min(a for a, _ in spans)
                if spans else 0.0)
        busy = sum(b - a for _, _, a, b in records)
        self._finalize_stats(stats, cache_base, t0)
        stats.occupancy = busy / (workers * stats.wall_time_s) \
            if stats.wall_time_s > 0 else 0.0
        return outs, stats

    def _finalize_stats(self, stats: PlanStats,
                        cache_base: Tuple[int, int], t0: float) -> None:
        stats.stage_invocations_saved = \
            stats.nodes_total - stats.nodes_executed
        hits, misses = self._cache_counters()
        stats.cache_hits = hits - cache_base[0]
        stats.cache_misses = misses - cache_base[1]
        stats.wall_time_s = time.perf_counter() - t0
        self.stats = stats
        self._record_run(stats)

    def _cache_counters(self) -> Tuple[int, int]:
        hits = misses = 0
        for node in self.nodes.values():
            cs = getattr(node.cache, "stats", None)
            if cs is not None:
                hits += cs.hits
                misses += cs.misses
        return hits, misses
