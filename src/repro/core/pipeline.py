"""Declarative pipeline algebra (the paper's §2.1 operator language).

Transformers are relations→relations functions combined with operators:

    >>   then / compose            %    rank cutoff
    +    linear combine            *    scalar product
    **   feature union             |    set union
    &    set intersection          ^    concatenate

Design points carried from the paper:
  * the *conceptual* pipeline is an expression tree; ``t % k`` is sugar
    for ``t >> RankCutoff(k)`` so that prefix precomputation (§3) can
    share ``t`` across pipelines with different cutoffs — exactly the
    demo experiment's structure;
  * transformers expose an equality property (structural ``signature()``)
    — the only requirement the paper's LCP algorithm places on them;
  * beyond the paper (§6 future work): transformers additionally declare
    ``key_columns`` / ``value_columns`` / ``deterministic`` /
    ``cacheable`` so caching strategies can be *inferred* and pipelines
    statically type-checked.
"""
from __future__ import annotations

import hashlib
from typing import Any, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from .frame import ColFrame

__all__ = [
    "Transformer", "Indexer", "Compose", "RankCutoff", "LinearCombine",
    "ScalarProduct", "FeatureUnion", "SetUnion", "SetIntersection",
    "Concatenate", "Identity", "GenericTransformer", "SourceResults",
    "add_ranks", "stages_of", "pipeline_hash",
]


def add_ranks(res: ColFrame) -> ColFrame:
    """(Re-)assign the rank column: descending score per qid, stable."""
    if len(res) == 0:
        return res.assign(rank=np.empty(0, dtype=np.int64)) if "rank" not in res \
            else res
    ranks = np.zeros(len(res), dtype=np.int64)
    for _, idx in res.group_indices(["qid"]).items():
        scores = res["score"][idx].astype(np.float64)
        docnos = res["docno"][idx]
        order = np.lexsort((np.asarray(docnos, dtype=object).astype(str), -scores))
        ranks[idx[order]] = np.arange(len(idx))
    return res.assign(rank=ranks)


class Transformer:
    """Base class for all pipeline stages."""

    #: required input / produced output columns (None = unconstrained)
    input_columns: Optional[frozenset] = None
    output_columns: Optional[frozenset] = None
    #: cache-strategy metadata (beyond-paper §6 future work)
    key_columns: Tuple[str, ...] = ()
    value_columns: Tuple[str, ...] = ()
    deterministic: bool = True
    cacheable: bool = True
    #: one-to-many stages (retrievers) need RetrieverCache not KeyValueCache
    one_to_many: bool = False

    # -- execution -----------------------------------------------------
    def transform(self, inp: ColFrame) -> ColFrame:
        raise NotImplementedError

    def __call__(self, inp: Any) -> ColFrame:
        frame = ColFrame.coerce(inp)
        if self.input_columns is not None:
            missing = self.input_columns - set(frame.columns)
            if missing and len(frame):
                raise TypeError(
                    f"{self!r} expected columns {sorted(self.input_columns)}, "
                    f"missing {sorted(missing)}")
        return self.transform(frame)

    # -- structural identity (paper §3: equality is all LCP needs) ------
    def signature(self) -> Tuple:
        return (type(self).__name__,)

    def __eq__(self, other) -> bool:
        return isinstance(other, Transformer) and self.signature() == other.signature()

    def __ne__(self, other) -> bool:
        return not self.__eq__(other)

    def __hash__(self) -> int:
        return hash(self.signature())

    def __repr__(self) -> str:
        return f"{type(self).__name__}{self.signature()[1:]}"

    # -- operator language ----------------------------------------------
    def __rshift__(self, other: "Transformer") -> "Compose":
        return Compose([self, other])

    def __mod__(self, k: int) -> "Compose":
        return Compose([self, RankCutoff(int(k))])

    def __add__(self, other: "Transformer") -> "LinearCombine":
        return LinearCombine(self, other)

    def __mul__(self, scalar: float) -> "ScalarProduct":
        return ScalarProduct(self, float(scalar))

    __rmul__ = __mul__

    def __pow__(self, other: "Transformer") -> "FeatureUnion":
        return FeatureUnion(self, other)

    def __or__(self, other: "Transformer") -> "SetUnion":
        return SetUnion(self, other)

    def __and__(self, other: "Transformer") -> "SetIntersection":
        return SetIntersection(self, other)

    def __xor__(self, other: "Transformer") -> "Concatenate":
        return Concatenate(self, other)


class Indexer(Transformer):
    """Terminal stage (D → ∅): consumes a corpus stream."""

    def index(self, corpus_iter: Iterable[dict]) -> Any:
        raise NotImplementedError

    def transform(self, inp: ColFrame) -> ColFrame:
        self.index(inp.to_dicts())
        return ColFrame()


class Compose(Transformer):
    """``>>`` — sequential composition; flattens nested composes."""

    def __init__(self, stages: Sequence[Transformer]):
        flat: List[Transformer] = []
        for s in stages:
            if isinstance(s, Compose):
                flat.extend(s.stages)
            else:
                flat.append(s)
        self.stages: Tuple[Transformer, ...] = tuple(flat)

    def transform(self, inp: ColFrame) -> ColFrame:
        out = inp
        for s in self.stages:
            out = s(out)
        return out

    def signature(self) -> Tuple:
        return ("Compose",) + tuple(s.signature() for s in self.stages)

    def __repr__(self) -> str:
        return " >> ".join(repr(s) for s in self.stages)

    def index(self, corpus_iter: Iterable[dict]):
        """Indexing pipeline: pass the stream through non-terminal stages,
        then hand it to the terminal indexer (paper §4.1/§4.4 usage)."""
        *head, last = self.stages
        stream: Iterable[dict] = corpus_iter

        def _apply(stage, it):
            frame = ColFrame.from_dicts(it)
            return stage(frame).to_dicts()

        for stage in head:
            if hasattr(stage, "transform_iter"):
                stream = stage.transform_iter(stream)
            else:
                stream = _apply(stage, stream)
        if not isinstance(last, Indexer) and not hasattr(last, "index"):
            raise TypeError(f"last stage of an indexing pipeline must be an "
                            f"Indexer, got {last!r}")
        return last.index(stream)


class RankCutoff(Transformer):
    """``% k`` — keep the top-k rows per query (by rank, else score)."""

    input_columns = frozenset({"qid", "docno", "score"})
    key_columns = ("qid",)

    def __init__(self, k: int):
        self.k = int(k)

    def transform(self, inp: ColFrame) -> ColFrame:
        if len(inp) == 0:
            return inp
        res = inp if "rank" in inp else add_ranks(inp)
        keep = res["rank"] < self.k
        return res.mask(keep)

    def signature(self) -> Tuple:
        return ("RankCutoff", self.k)


class _Binary(Transformer):
    def __init__(self, left: Transformer, right: Transformer):
        self.left = left
        self.right = right

    def signature(self) -> Tuple:
        return (type(self).__name__, self.left.signature(), self.right.signature())


class LinearCombine(_Binary):
    """``+`` — sum query-document scores of the two result lists."""

    def transform(self, inp: ColFrame) -> ColFrame:
        a, b = self.left(inp), self.right(inp)
        return _combine_scores(a, b, lambda x, y: x + y)


class ScalarProduct(Transformer):
    """``*`` — multiply scores by a scalar."""

    def __init__(self, inner: Transformer, scalar: float):
        self.inner = inner
        self.scalar = scalar

    def transform(self, inp: ColFrame) -> ColFrame:
        res = self.inner(inp)
        return add_ranks(res.assign(score=res["score"] * self.scalar))

    def signature(self) -> Tuple:
        return ("ScalarProduct", self.inner.signature(), self.scalar)


class FeatureUnion(_Binary):
    """``**`` — combine the two result lists as a features column."""

    def transform(self, inp: ColFrame) -> ColFrame:
        a, b = self.left(inp), self.right(inp)
        keys_a = a.key_tuples(["qid", "docno"])
        keys_b = b.key_tuples(["qid", "docno"])
        sb = dict(zip(keys_b, b["score"].tolist()))
        sa = dict(zip(keys_a, a["score"].tolist()))
        all_keys = list(dict.fromkeys(keys_a + keys_b))
        feats = np.empty(len(all_keys), dtype=object)
        for i, k in enumerate(all_keys):
            feats[i] = np.array([sa.get(k, 0.0), sb.get(k, 0.0)], dtype=np.float64)
        qids = np.empty(len(all_keys), dtype=object)
        docnos = np.empty(len(all_keys), dtype=object)
        qids[:] = [k[0] for k in all_keys]
        docnos[:] = [k[1] for k in all_keys]
        out = ColFrame({"qid": qids, "docno": docnos,
                        "score": np.array([f[0] for f in feats]),
                        "features": feats})
        return add_ranks(out)


class SetUnion(_Binary):
    """``|`` — set union of documents (scores/ranks dropped)."""

    def transform(self, inp: ColFrame) -> ColFrame:
        a, b = self.left(inp), self.right(inp)
        merged = ColFrame.concat([a, b])
        keep = [c for c in merged.columns if c not in ("score", "rank")]
        return merged.select(keep).dedup(["qid", "docno"])


class SetIntersection(_Binary):
    """``&`` — set intersection of documents (scores/ranks dropped)."""

    def transform(self, inp: ColFrame) -> ColFrame:
        a, b = self.left(inp), self.right(inp)
        bk = set(b.key_tuples(["qid", "docno"]))
        mask = np.array([k in bk for k in a.key_tuples(["qid", "docno"])],
                        dtype=bool)
        keep = [c for c in a.columns if c not in ("score", "rank")]
        return a.mask(mask).select(keep).dedup(["qid", "docno"])


class Concatenate(_Binary):
    """``^`` — append right results below the left results per query."""

    def transform(self, inp: ColFrame) -> ColFrame:
        a, b = self.left(inp), self.right(inp)
        if len(a) == 0:
            return add_ranks(b)
        ak = set(a.key_tuples(["qid", "docno"]))
        mask = np.array([k not in ak for k in b.key_tuples(["qid", "docno"])],
                        dtype=bool)
        b_new = b.mask(mask)
        # offset right scores so they sort strictly below the left block
        if len(b_new):
            min_a = {}
            for (qid,), idx in a.group_indices(["qid"]).items():
                min_a[qid] = float(a["score"][idx].min())
            max_b = {}
            for (qid,), idx in b_new.group_indices(["qid"]).items():
                max_b[qid] = float(b_new["score"][idx].max())
            shift = np.array([
                min_a.get(q, 0.0) - max_b.get(q, 0.0) - 1.0
                for q in b_new["qid"].tolist()])
            b_new = b_new.assign(score=b_new["score"] + shift)
        common = [c for c in a.columns if c in b_new.columns] or list(a.columns)
        out = ColFrame.concat([a.select(common), b_new.select(common)]) \
            if len(b_new) else a
        return add_ranks(out)


class Identity(Transformer):
    """Returns its input unchanged (paper §2.2's pass-through)."""

    def transform(self, inp: ColFrame) -> ColFrame:
        return inp


class SourceResults(Transformer):
    """A constant result set as a pipeline stage (paper §2.2's
    ``pt.Transformer.from_df(res)`` pattern): joins the stored results
    back onto the incoming queries."""

    def __init__(self, results: ColFrame, name: str = "source"):
        self.results = results
        self.name = name

    def transform(self, inp: ColFrame) -> ColFrame:
        if len(inp) == 0 or "qid" not in inp:
            return self.results
        qids = set(inp["qid"].tolist())
        mask = np.array([q in qids for q in self.results["qid"].tolist()],
                        dtype=bool)
        return self.results.mask(mask)

    def signature(self) -> Tuple:
        return ("SourceResults", self.name, len(self.results))


class GenericTransformer(Transformer):
    """Wrap a plain function as a transformer (named for equality)."""

    def __init__(self, fn, name: str, *, key_columns=(), value_columns=(),
                 one_to_many=False, cacheable=True, deterministic=True,
                 params: Tuple = ()):
        self.fn = fn
        self.name = name
        self.params = tuple(params)
        self.key_columns = tuple(key_columns)
        self.value_columns = tuple(value_columns)
        self.one_to_many = one_to_many
        self.cacheable = cacheable
        self.deterministic = deterministic

    def transform(self, inp: ColFrame) -> ColFrame:
        return ColFrame.coerce(self.fn(inp))

    def signature(self) -> Tuple:
        return ("GenericTransformer", self.name) + self.params


def _combine_scores(a: ColFrame, b: ColFrame, op) -> ColFrame:
    keys_a = a.key_tuples(["qid", "docno"])
    keys_b = b.key_tuples(["qid", "docno"])
    sa = dict(zip(keys_a, a["score"].tolist()))
    sb = dict(zip(keys_b, b["score"].tolist()))
    all_keys = list(dict.fromkeys(keys_a + keys_b))
    scores = np.array([op(sa.get(k, 0.0), sb.get(k, 0.0)) for k in all_keys])
    qids = np.empty(len(all_keys), dtype=object)
    docnos = np.empty(len(all_keys), dtype=object)
    qids[:] = [k[0] for k in all_keys]
    docnos[:] = [k[1] for k in all_keys]
    return add_ranks(ColFrame({"qid": qids, "docno": docnos, "score": scores}))


# ---------------------------------------------------------------------------
# pipeline introspection helpers (used by precompute + caches)
# ---------------------------------------------------------------------------

def stages_of(pipeline: Transformer) -> Tuple[Transformer, ...]:
    """The sequential stage decomposition used by LCP (Compose chains
    decompose; every other node is a single opaque stage)."""
    if isinstance(pipeline, Compose):
        return pipeline.stages
    return (pipeline,)


def pipeline_hash(t: Transformer) -> str:
    """Stable hex digest of a transformer's structural signature."""
    return hashlib.sha256(repr(t.signature()).encode()).hexdigest()[:16]
