"""Declarative pipeline algebra (the paper's §2.1 operator language).

Transformers are relations→relations functions combined with operators:

    >>   then / compose            %    rank cutoff
    +    linear combine            *    scalar product
    **   feature union             |    set union
    &    set intersection          ^    concatenate

Design points carried from the paper:
  * the *conceptual* pipeline is an expression tree; ``t % k`` is sugar
    for ``t >> RankCutoff(k)`` so that prefix precomputation (§3) can
    share ``t`` across pipelines with different cutoffs — exactly the
    demo experiment's structure;
  * transformers expose an equality property (structural ``signature()``)
    — the only requirement the paper's LCP algorithm places on them;
  * beyond the paper (§6 future work): transformers additionally declare
    ``key_columns`` / ``value_columns`` / ``deterministic`` /
    ``cacheable`` so caching strategies can be *inferred* and pipelines
    statically type-checked.
"""
from __future__ import annotations

import hashlib
from typing import Any, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from .frame import ColFrame

__all__ = [
    "Transformer", "Indexer", "Compose", "RankCutoff", "LinearCombine",
    "ScalarProduct", "FeatureUnion", "SetUnion", "SetIntersection",
    "Concatenate", "Identity", "GenericTransformer", "SourceResults",
    "add_ranks", "stages_of", "pipeline_hash",
]


def _factorize(col: np.ndarray) -> np.ndarray:
    """Integer codes for a column.

    Keys are compared by string form — the same semantics as
    ``ColFrame.group_indices`` (``frame._row_codes``), so ``qid=1`` and
    ``qid="1"`` are one key throughout the algebra.  Q/R/RA relations
    in this codebase use string keys.
    """
    arr = np.asarray(col)
    if arr.dtype == object or arr.dtype.kind in ("U", "S"):
        arr = arr.astype(str)
    _, inv = np.unique(arr, return_inverse=True)
    return inv.astype(np.int64)


def _score_sort_keys(scores: np.ndarray) -> np.ndarray:
    """Unsigned-integer keys whose ascending order is descending score
    (IEEE-754 total order trick) — integer sorts beat float sorts."""
    ub = np.ascontiguousarray(scores).view(np.uint64)
    asc = np.where(ub >> np.uint64(63) == np.uint64(1),
                   ~ub, ub | np.uint64(1 << 63))
    return ~asc


def _repair_tied_group(res: ColFrame, ranks: np.ndarray,
                       idx: np.ndarray) -> None:
    """Re-rank one qid group with the full (docno, -score) tie-break."""
    scores = res["score"][idx].astype(np.float64)
    docnos = np.asarray(res["docno"][idx], dtype=object).astype(str)
    order = np.lexsort((docnos, -scores))
    ranks[idx[order]] = np.arange(len(idx))


def add_ranks(res: ColFrame) -> ColFrame:
    """(Re-)assign the rank column: descending score per qid, stable
    (ties broken by docno, then original position).

    Vectorized (benchmarked in ``benchmarks/plan_bench.py``):

    * results arriving qid-blocked (the overwhelmingly common layout a
      retriever emits) are scattered into a padded (groups × depth)
      matrix and ranked with one row-wise argsort;
    * otherwise a global two-pass argsort on (integer score keys, qid
      codes) is used;
    * docno strings are only compared inside groups that actually
      contain score ties, so the hot path never touches them.
    """
    if len(res) == 0:
        return res.assign(rank=np.empty(0, dtype=np.int64)) if "rank" not in res \
            else res
    n = len(res)
    scores = np.ascontiguousarray(res["score"].astype(np.float64, copy=False))
    q = res["qid"]
    pos = np.arange(n, dtype=np.int64)
    change = np.empty(n, dtype=bool)
    change[0] = True
    change[1:] = q[1:] != q[:-1]
    starts = np.nonzero(change)[0]
    n_runs = len(starts)
    reps = np.asarray(q[change])
    if reps.dtype == object or reps.dtype.kind in ("U", "S"):
        reps = reps.astype(str)
    uniq, rinv = np.unique(reps, return_inverse=True)
    lengths = np.diff(np.append(starts, n))
    depth = int(lengths.max())

    if len(uniq) == n_runs and n_runs * depth <= 4 * n + 1024:
        # -- blocked fast path: every qid is one contiguous run ----------
        uniform = depth == int(lengths.min())
        if uniform:
            # uniform fan-out (top-k results): a zero-copy reshape
            mat = scores.reshape(n_runs, depth)
        else:
            run_id = np.repeat(np.arange(n_runs, dtype=np.int64), lengths)
            col = pos - np.repeat(starts, lengths)
            mat = np.full((n_runs, depth), np.nan)  # NaN pads sort last
            mat[run_id, col] = scores
        order2d = np.argsort(-mat, axis=1, kind="stable")
        rr = np.empty((n_runs, depth), dtype=order2d.dtype)
        np.put_along_axis(rr, order2d,
                          np.broadcast_to(np.arange(depth), (n_runs, depth)),
                          axis=1)
        ranks = rr.ravel().astype(np.int64, copy=False) if uniform \
            else rr[run_id, col].astype(np.int64, copy=False)
        srt = np.take_along_axis(mat, order2d, axis=1)
        tied_rows = np.nonzero((srt[:, 1:] == srt[:, :-1]).any(axis=1))[0]
        if len(tied_rows):
            ranks = np.ascontiguousarray(ranks)
            for r0 in tied_rows:
                idx = np.arange(starts[r0], starts[r0] + lengths[r0])
                _repair_tied_group(res, ranks, idx)
        return res.assign(rank=ranks)

    # -- general path: interleaved or heavily skewed groups --------------
    run_id = np.repeat(np.arange(n_runs, dtype=np.int64), lengths)
    qcodes = rinv.astype(np.int64)[run_id]
    o1 = np.argsort(_score_sort_keys(scores), kind="stable")
    o2 = np.argsort(qcodes[o1], kind="stable")
    order = o1[o2]
    qs = qcodes[order]
    ss = scores[order]
    tie = np.zeros(n, dtype=bool)
    tie[1:] = (qs[1:] == qs[:-1]) & (ss[1:] == ss[:-1])
    if tie.any():
        docnos = np.asarray(res["docno"], dtype=object)
        bounds = np.nonzero(np.diff(
            np.concatenate([[0], tie.view(np.int8), [0]])))[0]
        for i in range(0, len(bounds), 2):
            lo, hi = bounds[i] - 1, bounds[i + 1]
            sub = order[lo:hi]
            # (docno, original position): the explicit position key keeps
            # +0.0/-0.0 score ties in row order like the seed's lexsort
            order[lo:hi] = sub[np.lexsort((sub, docnos[sub].astype(str)))]
        qs = qcodes[order]
    new_block = np.empty(n, dtype=bool)
    new_block[0] = True
    new_block[1:] = qs[1:] != qs[:-1]
    block_start = np.maximum.accumulate(np.where(new_block, pos, 0))
    ranks = np.empty(n, dtype=np.int64)
    ranks[order] = pos - block_start
    return res.assign(rank=ranks)


class Transformer:
    """Base class for all pipeline stages."""

    #: required input / produced output columns (None = unconstrained)
    input_columns: Optional[frozenset] = None
    output_columns: Optional[frozenset] = None
    #: cache-strategy metadata (beyond-paper §6 future work)
    key_columns: Tuple[str, ...] = ()
    value_columns: Tuple[str, ...] = ()
    deterministic: bool = True
    cacheable: bool = True
    #: one-to-many stages (retrievers) need RetrieverCache not KeyValueCache
    one_to_many: bool = False
    #: row-local per qid: output for a qid group depends only on that
    #: group's rows.  Stages computing cross-query statistics (global
    #: score normalization, corpus-level IDF updates, ...) must declare
    #: ``shardable=False`` — the concurrent executor then refuses to
    #: partition the query frame (``core/plan.py``), like ``batch_size``
    #: callers must refuse to batch them.
    shardable: bool = True
    #: declares that ``RankCutoff`` commutes through this stage: it is a
    #: per-row mapping (rows 1:1, no reordering) that preserves the
    #: per-qid ranking — same (qid, docno, rank) — so ``t >> X >> % k``
    #: equals ``t >> % k >> X``.  The optimizer (``core/rewrite.py``)
    #: uses this to push rank cutoffs toward retrievers.  Stages whose
    #: score map can reorder ties must leave this False.
    rank_preserving: bool = False
    #: declares that the output is the input frame plus extra columns —
    #: existing columns, row count and row order are untouched (e.g. a
    #: text loader).  Implies ``rank_preserving``-like row stability and
    #: lets cache-aware pruning defer the stage behind a warm
    #: downstream cache whose keys the stage cannot alter.
    augment_only: bool = False

    # -- execution -----------------------------------------------------
    def transform(self, inp: ColFrame) -> ColFrame:
        raise NotImplementedError

    def __call__(self, inp: Any) -> ColFrame:
        frame = ColFrame.coerce(inp)
        if self.input_columns is not None:
            missing = self.input_columns - set(frame.columns)
            if missing and len(frame):
                raise TypeError(
                    f"{self!r} expected columns {sorted(self.input_columns)}, "
                    f"missing {sorted(missing)}")
        return self.transform(frame)

    # -- structural identity (paper §3: equality is all LCP needs) ------
    def signature(self) -> Tuple:
        return (type(self).__name__,)

    # -- provenance (beyond paper: cache invalidation) -------------------
    def fingerprint(self) -> str:
        """Stable provenance fingerprint (hex): class identity + config
        (``signature()``) + ``fingerprint_extras()``, hashed by the
        ``cachekey_hash`` kernel digest (``caching/provenance.py``).
        Deterministic across processes; used by the cache manifests to
        detect stale cache directories."""
        from ..caching.provenance import transformer_fingerprint
        return transformer_fingerprint(self)

    def fingerprint_extras(self) -> Tuple:
        """Extra provenance tokens folded into ``fingerprint()``.

        Override to declare behaviour-relevant state the signature
        misses — corpus versions, checkpoint paths, model revisions —
        so caches of this transformer invalidate when they change."""
        return ()

    # -- optimizer hooks (core/rewrite.py) -------------------------------
    def with_cutoff(self, k: int) -> Optional["Transformer"]:
        """Absorb a downstream ``RankCutoff(k)``: return a transformer
        equivalent to ``self >> RankCutoff(k)`` (return ``self`` when
        this stage already emits at most ``k`` results per query), or
        ``None`` when the cutoff cannot be absorbed.  Retrievers with a
        ``num_results`` knob override this so the optimizer's pushdown
        pass fuses ``% k`` into the retrieval depth itself."""
        return None

    def __eq__(self, other) -> bool:
        return isinstance(other, Transformer) and self.signature() == other.signature()

    def __ne__(self, other) -> bool:
        return not self.__eq__(other)

    def __hash__(self) -> int:
        return hash(self.signature())

    def __repr__(self) -> str:
        return f"{type(self).__name__}{self.signature()[1:]}"

    # -- operator language ----------------------------------------------
    def __rshift__(self, other: "Transformer") -> "Compose":
        return Compose([self, other])

    def __mod__(self, k: int) -> "Compose":
        return Compose([self, RankCutoff(int(k))])

    def __add__(self, other: "Transformer") -> "LinearCombine":
        return LinearCombine(self, other)

    def __mul__(self, scalar: float) -> "ScalarProduct":
        return ScalarProduct(self, float(scalar))

    __rmul__ = __mul__

    def __pow__(self, other: "Transformer") -> "FeatureUnion":
        return FeatureUnion(self, other)

    def __or__(self, other: "Transformer") -> "SetUnion":
        return SetUnion(self, other)

    def __and__(self, other: "Transformer") -> "SetIntersection":
        return SetIntersection(self, other)

    def __xor__(self, other: "Transformer") -> "Concatenate":
        return Concatenate(self, other)


class Indexer(Transformer):
    """Terminal stage (D → ∅): consumes a corpus stream."""

    def index(self, corpus_iter: Iterable[dict]) -> Any:
        raise NotImplementedError

    def transform(self, inp: ColFrame) -> ColFrame:
        self.index(inp.to_dicts())
        return ColFrame()


class Compose(Transformer):
    """``>>`` — sequential composition; flattens nested composes."""

    def __init__(self, stages: Sequence[Transformer]):
        flat: List[Transformer] = []
        for s in stages:
            if isinstance(s, Compose):
                flat.extend(s.stages)
            else:
                flat.append(s)
        self.stages: Tuple[Transformer, ...] = tuple(flat)

    def transform(self, inp: ColFrame) -> ColFrame:
        out = inp
        for s in self.stages:
            out = s(out)
        return out

    def signature(self) -> Tuple:
        return ("Compose",) + tuple(s.signature() for s in self.stages)

    def __repr__(self) -> str:
        return " >> ".join(repr(s) for s in self.stages)

    def index(self, corpus_iter: Iterable[dict]):
        """Indexing pipeline: pass the stream through non-terminal stages,
        then hand it to the terminal indexer (paper §4.1/§4.4 usage)."""
        *head, last = self.stages
        stream: Iterable[dict] = corpus_iter

        def _apply(stage, it):
            frame = ColFrame.from_dicts(it)
            return stage(frame).to_dicts()

        for stage in head:
            if hasattr(stage, "transform_iter"):
                stream = stage.transform_iter(stream)
            else:
                stream = _apply(stage, stream)
        if not isinstance(last, Indexer) and not hasattr(last, "index"):
            raise TypeError(f"last stage of an indexing pipeline must be an "
                            f"Indexer, got {last!r}")
        return last.index(stream)


class RankCutoff(Transformer):
    """``% k`` — keep the top-k rows per query (by rank, else score)."""

    input_columns = frozenset({"qid", "docno", "score"})
    key_columns = ("qid",)

    def __init__(self, k: int):
        self.k = int(k)

    def transform(self, inp: ColFrame) -> ColFrame:
        if len(inp) == 0:
            return inp
        res = inp if "rank" in inp else add_ranks(inp)
        keep = res["rank"] < self.k
        return res.mask(keep)

    def signature(self) -> Tuple:
        return ("RankCutoff", self.k)

    def with_cutoff(self, k: int) -> "RankCutoff":
        """``% j >> % k`` is ``% min(j, k)``."""
        return self if min(self.k, int(k)) == self.k \
            else RankCutoff(min(self.k, int(k)))


class _Binary(Transformer):
    """Binary operator node.

    ``transform`` evaluates both children then delegates to
    ``combine(a, b)``; the execution planner (``core/plan.py``) calls
    ``combine`` directly on shared child results, so a retriever shared
    under ``a + b`` and ``a ** c`` executes once.

    ``commutative=True`` declares ``combine(a, b)`` and ``combine(b, a)``
    produce the same per-qid relation — same (qid, docno) rows with the
    same scores/ranks, though possibly in a different row order — which
    lets the optimizer's normalize pass share ``a + b`` with ``b + a``.
    """

    #: combine(a, b) == combine(b, a) up to row order
    commutative: bool = False

    def __init__(self, left: Transformer, right: Transformer):
        self.left = left
        self.right = right

    def signature(self) -> Tuple:
        return (type(self).__name__, self.left.signature(), self.right.signature())

    def transform(self, inp: ColFrame) -> ColFrame:
        return self.combine(self.left(inp), self.right(inp))

    def combine(self, a: ColFrame, b: ColFrame) -> ColFrame:
        raise NotImplementedError


class LinearCombine(_Binary):
    """``+`` — sum query-document scores of the two result lists."""

    commutative = True                   # x + y == y + x per (qid, docno)

    def combine(self, a: ColFrame, b: ColFrame) -> ColFrame:
        return _combine_scores(a, b, lambda x, y: x + y)


class ScalarProduct(Transformer):
    """``*`` — multiply scores by a scalar."""

    def __init__(self, inner: Transformer, scalar: float):
        self.inner = inner
        self.scalar = scalar

    def transform(self, inp: ColFrame) -> ColFrame:
        return self.apply(self.inner(inp))

    def apply(self, res: ColFrame) -> ColFrame:
        """Post-child work (planner entry point, like _Binary.combine)."""
        return add_ranks(res.assign(score=res["score"] * self.scalar))

    def signature(self) -> Tuple:
        return ("ScalarProduct", self.inner.signature(), self.scalar)


class FeatureUnion(_Binary):
    """``**`` — combine the two result lists as a features column."""

    def combine(self, a: ColFrame, b: ColFrame) -> ColFrame:
        qids, docnos, sa, sb = _aligned_scores(a, b)
        feats = np.empty(len(qids), dtype=object)
        if len(qids):
            feats[:] = list(np.stack([sa, sb], axis=1))
        out = ColFrame({"qid": qids, "docno": docnos,
                        "score": sa.copy(), "features": feats})
        return add_ranks(out)


class SetUnion(_Binary):
    """``|`` — set union of documents (scores/ranks dropped)."""

    commutative = True                   # same (qid, docno) set either way

    def combine(self, a: ColFrame, b: ColFrame) -> ColFrame:
        merged = ColFrame.concat([a, b])
        keep = [c for c in merged.columns if c not in ("score", "rank")]
        return merged.select(keep).dedup(["qid", "docno"])


class SetIntersection(_Binary):
    """``&`` — set intersection of documents (scores/ranks dropped)."""

    def combine(self, a: ColFrame, b: ColFrame) -> ColFrame:
        mask = _key_membership(a, b) if len(a) and len(b) else \
            np.zeros(len(a), dtype=bool)
        keep = [c for c in a.columns if c not in ("score", "rank")]
        return a.mask(mask).select(keep).dedup(["qid", "docno"])


class Concatenate(_Binary):
    """``^`` — append right results below the left results per query."""

    def combine(self, a: ColFrame, b: ColFrame) -> ColFrame:
        if len(a) == 0:
            return add_ranks(b)
        mask = ~_key_membership(b, a) if len(b) else \
            np.zeros(0, dtype=bool)
        b_new = b.mask(mask)
        # offset right scores so they sort strictly below the left block
        if len(b_new):
            qcodes = _factorize(_obj_concat(a["qid"], b_new["qid"]))
            qa, qb = qcodes[:len(a)], qcodes[len(a):]
            n_codes = int(qcodes.max()) + 1
            min_a = np.full(n_codes, np.inf)
            np.minimum.at(min_a, qa, a["score"].astype(np.float64))
            min_a[np.isinf(min_a)] = 0.0   # qids absent from a -> 0.0
            max_b = np.full(n_codes, -np.inf)
            np.maximum.at(max_b, qb, b_new["score"].astype(np.float64))
            shift = min_a[qb] - max_b[qb] - 1.0
            b_new = b_new.assign(score=b_new["score"] + shift)
        common = [c for c in a.columns if c in b_new.columns] or list(a.columns)
        out = ColFrame.concat([a.select(common), b_new.select(common)]) \
            if len(b_new) else a
        return add_ranks(out)


class Identity(Transformer):
    """Returns its input unchanged (paper §2.2's pass-through)."""

    def transform(self, inp: ColFrame) -> ColFrame:
        return inp


class SourceResults(Transformer):
    """A constant result set as a pipeline stage (paper §2.2's
    ``pt.Transformer.from_df(res)`` pattern): joins the stored results
    back onto the incoming queries."""

    def __init__(self, results: ColFrame, name: str = "source"):
        self.results = results
        self.name = name

    def transform(self, inp: ColFrame) -> ColFrame:
        if len(inp) == 0 or "qid" not in inp:
            return self.results
        qids = set(inp["qid"].tolist())
        mask = np.array([q in qids for q in self.results["qid"].tolist()],
                        dtype=bool)
        return self.results.mask(mask)

    def signature(self) -> Tuple:
        return ("SourceResults", self.name, len(self.results))


class GenericTransformer(Transformer):
    """Wrap a plain function as a transformer (named for equality)."""

    def __init__(self, fn, name: str, *, key_columns=(), value_columns=(),
                 one_to_many=False, cacheable=True, deterministic=True,
                 shardable=True, rank_preserving=False, augment_only=False,
                 params: Tuple = ()):
        self.fn = fn
        self.name = name
        self.params = tuple(params)
        self.key_columns = tuple(key_columns)
        self.value_columns = tuple(value_columns)
        self.one_to_many = one_to_many
        self.cacheable = cacheable
        self.deterministic = deterministic
        self.shardable = shardable
        self.rank_preserving = rank_preserving
        self.augment_only = augment_only

    def transform(self, inp: ColFrame) -> ColFrame:
        return ColFrame.coerce(self.fn(inp))

    def signature(self) -> Tuple:
        return ("GenericTransformer", self.name) + self.params


def _obj_concat(x: np.ndarray, y: np.ndarray) -> np.ndarray:
    out = np.empty(len(x) + len(y), dtype=object)
    out[:len(x)] = x
    out[len(x):] = y
    return out


def _merged_keys(a: ColFrame, b: ColFrame
                 ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Concatenated (qid, docno) columns of a and b plus integer codes
    identifying distinct key pairs across both frames."""
    merged_q = _obj_concat(a["qid"], b["qid"])
    merged_d = _obj_concat(a["docno"], b["docno"])
    qcodes = _factorize(merged_q)
    dcodes = _factorize(merged_d)
    return merged_q, merged_d, \
        qcodes * (int(dcodes.max(initial=0)) + 1) + dcodes


def _key_membership(a: ColFrame, b: ColFrame) -> np.ndarray:
    """Boolean mask: which rows of ``a`` have their (qid, docno) in ``b``."""
    _, _, codes = _merged_keys(a, b)
    return np.isin(codes[:len(a)], codes[len(a):])


def _aligned_scores(a: ColFrame, b: ColFrame
                    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Row-align two result frames on (qid, docno), vectorized.

    Returns ``(qids, docnos, scores_a, scores_b)`` over the union of
    keys in first-occurrence order (a's rows, then b's new keys);
    missing scores are 0.0 and duplicate keys within one frame keep the
    last score — the exact semantics of the seed's dict-based loop,
    without per-key Python work.
    """
    na, nb = len(a), len(b)
    if na + nb == 0:
        e = np.empty(0, dtype=object)
        return e, e.copy(), np.empty(0), np.empty(0)
    merged_q, merged_d, codes = _merged_keys(a, b)
    uniq, first, inv = np.unique(codes, return_index=True, return_inverse=True)
    perm = np.argsort(first, kind="stable")      # sorted-uniq -> output order
    inv_perm = np.empty(len(perm), dtype=np.int64)
    inv_perm[perm] = np.arange(len(perm))
    slot = inv_perm[inv]                          # row -> output slot
    k = len(uniq)
    sa = np.zeros(k)
    sb = np.zeros(k)
    if na:
        sa[slot[:na]] = a["score"].astype(np.float64)   # dup keys: last wins
    if nb:
        sb[slot[na:]] = b["score"].astype(np.float64)
    rep = first[perm]                             # first occurrence per key
    return merged_q[rep], merged_d[rep], sa, sb


def _combine_scores(a: ColFrame, b: ColFrame, op) -> ColFrame:
    qids, docnos, sa, sb = _aligned_scores(a, b)
    scores = np.asarray(op(sa, sb), dtype=np.float64)
    return add_ranks(ColFrame({"qid": qids, "docno": docnos, "score": scores}))


# ---------------------------------------------------------------------------
# pipeline introspection helpers (used by precompute + caches)
# ---------------------------------------------------------------------------

def stages_of(pipeline: Transformer) -> Tuple[Transformer, ...]:
    """The sequential stage decomposition used by LCP (Compose chains
    decompose; every other node is a single opaque stage)."""
    if isinstance(pipeline, Compose):
        return pipeline.stages
    return (pipeline,)


def pipeline_hash(t: Transformer) -> str:
    """Stable hex digest of a transformer's structural signature."""
    return hashlib.sha256(repr(t.signature()).encode()).hexdigest()[:16]
