# The paper's primary contribution: declarative IR pipelines (relation
# store + transformer algebra), prefix precomputation in experiments,
# and the Experiment abstraction.
from .frame import ColFrame, Q, D, R, RA, relation_of
from .pipeline import (Transformer, Indexer, Compose, RankCutoff,
                       LinearCombine, ScalarProduct, FeatureUnion, SetUnion,
                       SetIntersection, Concatenate, Identity,
                       GenericTransformer, SourceResults, add_ranks,
                       stages_of, pipeline_hash)
from .precompute import (longest_common_prefix, split_on_prefix,
                         run_with_precompute, PrefixTrie, run_with_trie,
                         PrecomputeStats)
from .ir import IRNode, PlanGraph, lower, render_explain
from .rewrite import OPTIMIZER_PASSES, PassStats
from .cost import CostContext, CostModel
from .plan import ExecutionPlan, PlanNode, PlanStats, plan_size
from .compile_opt import compile_pipeline
from .measures import Measure, parse_measure, evaluate
from .experiment import Experiment, ExperimentResult

__all__ = [
    "ColFrame", "Q", "D", "R", "RA", "relation_of",
    "Transformer", "Indexer", "Compose", "RankCutoff", "LinearCombine",
    "ScalarProduct", "FeatureUnion", "SetUnion", "SetIntersection",
    "Concatenate", "Identity", "GenericTransformer", "SourceResults",
    "add_ranks", "stages_of", "pipeline_hash",
    "longest_common_prefix", "split_on_prefix", "run_with_precompute",
    "PrefixTrie", "run_with_trie", "PrecomputeStats",
    "ExecutionPlan", "PlanNode", "PlanStats", "plan_size",
    "IRNode", "PlanGraph", "lower", "render_explain",
    "OPTIMIZER_PASSES", "PassStats", "CostContext", "CostModel",
    "compile_pipeline", "Measure", "parse_measure", "evaluate",
    "Experiment", "ExperimentResult",
]
