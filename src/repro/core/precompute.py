"""Prefix precomputation for comparative experiments (paper §3).

The paper's contribution: when ``Experiment()`` evaluates a set of
pipelines ``P = {p_1 … p_M}``, identify the longest common prefix

    LCP(P) = argmax_cp { ||cp||  s.t.  cp[j] == p_i[j]  ∀ i, 1…j }   (Eq. 2)

execute it once on the queries, and feed the interim results into each
*remainder* pipeline ``p̂_i = p_i[||LCP(P)|| .. ||p_i||]``.  The only
requirement placed on transformers is an equality property — provided
structurally by ``Transformer.signature()``.

Beyond the paper (its §6 names this as an open limitation): the LCP
misses prefixes shared by only a *subset* of pipelines, e.g. the
ablation ``A;  A»B;  A»B»C`` only precomputes ``A`` even though ``A»B``
is shared by two pipelines.  ``PrefixTrie`` executes each shared trie
node exactly once, which strictly dominates LCP (and degenerates to LCP
when every prefix is common to all pipelines).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from .frame import ColFrame
from .pipeline import Compose, Identity, Transformer, stages_of

__all__ = [
    "longest_common_prefix", "split_on_prefix", "run_with_precompute",
    "PrefixTrie", "run_with_trie", "PrecomputeStats",
]


# ---------------------------------------------------------------------------
# LCP (paper §3, Eq. 2)
# ---------------------------------------------------------------------------

def longest_common_prefix(
        pipelines: Sequence[Transformer]) -> Tuple[Transformer, ...]:
    """The longest common prefix of the stage decompositions (Eq. 2).

    Only assumes stage equality (``==`` via structural signatures).
    Returns a (possibly empty) tuple of stages.
    """
    if not pipelines:
        return ()
    stage_lists = [stages_of(p) for p in pipelines]
    limit = min(len(s) for s in stage_lists)
    prefix: List[Transformer] = []
    for j in range(limit):
        first = stage_lists[0][j]
        if all(sl[j] == first for sl in stage_lists[1:]):
            prefix.append(first)
        else:
            break
    return tuple(prefix)


def split_on_prefix(pipeline: Transformer,
                    prefix_len: int) -> Transformer:
    """The remainder pipeline  p̂ = p[prefix_len .. ||p||]."""
    stages = stages_of(pipeline)
    rest = stages[prefix_len:]
    if not rest:
        return Identity()
    if len(rest) == 1:
        return rest[0]
    return Compose(rest)


@dataclass
class PrecomputeStats:
    """Accounting for how much work precomputation avoided."""
    prefix_len: int = 0
    n_pipelines: int = 0
    stage_invocations_saved: int = 0     # (#pipelines-1) × prefix_len (LCP)
    nodes_executed: int = 0              # trie mode: executed trie nodes
    nodes_total: int = 0                 # trie mode: Σ stages over pipelines


def run_with_precompute(
        pipelines: Sequence[Transformer],
        queries: ColFrame,
        *,
        batch_size: Optional[int] = None,
        n_shards: Optional[int] = None,
        max_workers: Optional[int] = None,
) -> Tuple[List[ColFrame], PrecomputeStats]:
    """Execute pipelines over `queries` sharing the LCP exactly once.

    Mirrors the semantics of running each pipeline independently (the
    cache-transparency invariant is asserted in tests).

    Thin wrapper over ``plan.ExecutionPlan`` (which shares strictly more
    than the LCP); the returned stats keep the paper-§3 accounting —
    ``stage_invocations_saved`` is the Eq. 2 quantity
    ``(|P|-1) × ||LCP(P)||`` — so callers comparing against the paper's
    tables see the LCP numbers.
    """
    from .plan import ExecutionPlan

    prefix = longest_common_prefix(pipelines)
    outs, plan_stats = ExecutionPlan(pipelines).run(
        queries, batch_size=batch_size, n_shards=n_shards,
        max_workers=max_workers)
    stats = PrecomputeStats(
        prefix_len=len(prefix), n_pipelines=len(pipelines),
        stage_invocations_saved=max(0, (len(pipelines) - 1)) * len(prefix),
        nodes_executed=plan_stats.nodes_executed,
        nodes_total=plan_stats.nodes_total)
    return outs, stats


def _run_stage(stage: Transformer, inp: ColFrame,
               batch_size: Optional[int]) -> ColFrame:
    if batch_size is None or len(inp) <= batch_size:
        return stage(inp)
    parts = []
    for lo in range(0, len(inp), batch_size):
        parts.append(stage(inp.take(range(lo, min(lo + batch_size, len(inp))))))
    return ColFrame.concat(parts)


# ---------------------------------------------------------------------------
# Beyond-paper: maximal-coverage prefix trie (§6 limitation resolved)
# ---------------------------------------------------------------------------

@dataclass
class _TrieNode:
    stage: Optional[Transformer] = None
    children: Dict[Tuple, "_TrieNode"] = field(default_factory=dict)
    #: indices of pipelines that *terminate* at this node
    terminal: List[int] = field(default_factory=list)

    def child(self, stage: Transformer) -> "_TrieNode":
        key = stage.signature()
        node = self.children.get(key)
        if node is None:
            node = _TrieNode(stage=stage)
            self.children[key] = node
        return node


class PrefixTrie:
    """A prefix trie over pipeline stage decompositions.

    Each node is executed at most once per ``run``; every pipeline
    re-uses every shared ancestor, not just the global LCP.  For the
    paper's §6 ablation case ``A; A»B; A»B»C`` the trie executes A once
    and B once (LCP executes A once but B twice).
    """

    def __init__(self, pipelines: Sequence[Transformer]):
        self.pipelines = list(pipelines)
        self.root = _TrieNode()
        for i, p in enumerate(self.pipelines):
            node = self.root
            for stage in stages_of(p):
                node = node.child(stage)
            node.terminal.append(i)

    # -- analysis ---------------------------------------------------------
    def n_nodes(self) -> int:
        count = 0
        stack = [self.root]
        while stack:
            node = stack.pop()
            count += len(node.children)
            stack.extend(node.children.values())
        return count

    def n_stage_invocations_naive(self) -> int:
        return sum(len(stages_of(p)) for p in self.pipelines)

    # -- execution ----------------------------------------------------------
    def run(self, queries: ColFrame,
            batch_size: Optional[int] = None,
            ) -> Tuple[List[ColFrame], PrecomputeStats]:
        outs: List[Optional[ColFrame]] = [None] * len(self.pipelines)
        executed = 0

        def visit(node: _TrieNode, interim: ColFrame):
            nonlocal executed
            for i in node.terminal:
                outs[i] = interim
            for child in node.children.values():
                res = _run_stage(child.stage, interim, batch_size)
                executed += 1
                visit(child, res)

        visit(self.root, queries)
        stats = PrecomputeStats(
            prefix_len=len(longest_common_prefix(self.pipelines)),
            n_pipelines=len(self.pipelines),
            nodes_executed=executed,
            nodes_total=self.n_stage_invocations_naive(),
            stage_invocations_saved=self.n_stage_invocations_naive() - executed,
        )
        return [o if o is not None else ColFrame() for o in outs], stats


def run_with_trie(pipelines: Sequence[Transformer], queries: ColFrame,
                  *, batch_size: Optional[int] = None,
                  n_shards: Optional[int] = None,
                  max_workers: Optional[int] = None,
                  ) -> Tuple[List[ColFrame], PrecomputeStats]:
    """Maximal-coverage sharing — thin wrapper over ``plan.ExecutionPlan``,
    which subsumes the trie (and additionally shares through binary
    operator nodes; ``PrefixTrie`` is kept for structural analysis)."""
    from .plan import ExecutionPlan

    return ExecutionPlan(pipelines).run(queries, batch_size=batch_size,
                                        n_shards=n_shards,
                                        max_workers=max_workers)
