"""Logical IR for the plan compiler (lowering layer).

``core/plan.py`` used to be a monolith: lowering, fingerprints, memo
insertion, manifests and two schedulers in one class.  The planner is
now a small compiler with three layers:

* **this module** — the logical IR: pipeline expressions are *lowered*
  into a forest of :class:`IRNode` DAG nodes, one node per syntactic
  operator occurrence, with the transformer metadata the optimizer
  needs (``relation`` type, ``shardable``, ``rank_preserving``,
  ``augment_only``) lifted onto the node at lowering time;
* ``core/rewrite.py`` — the optimizer: an ordered pass pipeline
  (normalize / cse / pushdown / cache-prune) rewriting the graph;
* ``core/executor.py`` — the physical layer: the sequential and
  sharded-wavefront schedulers, semantics unchanged.

Lowering itself performs **no sharing**: ``optimize="none"`` executes
the forest as-is (one invocation per syntactic occurrence — the naive
baseline of the source paper's tables), and every bit of sharing is an
explicit, accounted optimizer pass.  ``ExecutionPlan`` (``core/plan.py``)
remains the façade over all three layers.

Nodes are value-like: the structural fields (``key``, ``kind``,
``stage``, lifted metadata) are fixed at construction and rewrite
passes build *new* nodes instead of editing structure in place; only
annotations (labels, memo caches, pass markers) are added after the
fact.

Invariants this layer guarantees (what the optimizer passes rely on):

* **one node per syntactic occurrence** — lowering never merges, so
  every unit of sharing is attributable to a named pass;
* **keys are structural identity** — two nodes with equal ``key``
  compute bit-identical frames from equal inputs (transformer equality
  is ``signature()`` equality and transformers are deterministic),
  which is the entire soundness argument of CSE;
* **metadata is lifted once and never edited** — ``rank_preserving``
  licenses pushdown to climb an edge, ``with_cutoff`` to absorb,
  ``augment_only`` licenses cache-prune to defer, ``shardable``
  licenses the executor to partition the query frame.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

from .frame import D, Q, R
from .pipeline import Compose, ScalarProduct, Transformer, _Binary

__all__ = ["IRNode", "PlanGraph", "lower", "make_stage_node", "node_key",
           "plan_size", "render_explain"]


@dataclass
class IRNode:
    """One unit of work in the logical DAG.

    ``key`` is the canonical *structural* identity (recursive over the
    inputs' keys) — two nodes with equal keys compute the same relation.
    ``id`` is the per-graph instance identity: before common-subexpression
    elimination several nodes may share a key, so executors and passes
    address nodes by ``id``, never by ``key``.
    """
    id: int
    key: Tuple                           # canonical structural key
    kind: str                            # "source" | "stage" | "combine" | "scale"
    stage: Optional[Transformer]         # operator instance (None for source)
    inputs: List["IRNode"] = field(default_factory=list)
    # -- metadata lifted from the Transformer at lowering time -------------
    relation: Optional[str] = None       # static Q/D/R classification
    shardable: bool = True               # row-local per qid (see pipeline.py)
    rank_preserving: bool = False        # RankCutoff commutes through it
    augment_only: bool = False           # output = input + extra columns
    # -- optimizer / executor annotations ----------------------------------
    canon_key: Optional[Tuple] = None    # normalize pass: commutative-canonical
    touched_by: List[str] = field(default_factory=list)
    cache: Optional[Transformer] = None  # planner-inserted memo wrapper
    probe_input: Optional["IRNode"] = None   # cache-prune: lookup-first input
    inline_chain: List["IRNode"] = field(default_factory=list)
    inlined: bool = False                # deferred into the consumer's task
    label: str = ""                      # unique display label
    # -- cost layer (core/cost.py) annotations -----------------------------
    cost_est_s: Optional[float] = None   # per-query estimate (seconds)
    cost_src: Optional[str] = None       # "measured" | "analytic" | "default"
    sched_priority: float = 0.0          # critical-path rank (operand-order)
    cache_skip: bool = False             # cache-place: cheaper to recompute
    backend_override: Optional[str] = None   # cache-place: hot-node promotion
    # -- asynchronous data plane (caching/dataplane.py) ---------------------
    #: plan-stamped: executors issue this node's cache reads on the I/O
    #: pool as soon as the feeding frame exists (False for graphs built
    #: outside ExecutionPlan — lowering alone never prefetches)
    prefetch: bool = False

    def __hash__(self) -> int:           # identity-hashed for set membership
        return self.id

    def __eq__(self, other) -> bool:
        return self is other


#: transformer classes whose combine output keeps scores (R relation)
_R_COMBINES = ("LinearCombine", "FeatureUnion", "Concatenate")


def _static_relation(kind: str, stage: Optional[Transformer]) -> Optional[str]:
    """Best-effort static output-relation classification for display."""
    if kind == "source":
        return "Q"
    if kind in ("scale",):
        return "R"
    if kind == "combine":
        return "R" if type(stage).__name__ in _R_COMBINES else "D"
    cols = getattr(stage, "output_columns", None)
    if cols:
        cols = set(cols)
        for name, req in (("R", R), ("Q", Q), ("D", D)):
            if req <= cols:
                return name
    if getattr(stage, "one_to_many", False):
        return "R"
    return None


class PlanGraph:
    """The lowered forest: nodes in topological order, source first."""

    def __init__(self, pipelines: Sequence[Transformer]):
        self.pipelines: List[Transformer] = list(pipelines)
        self._next_id = 0
        self.source = IRNode(id=self._take_id(), key=("source",),
                             kind="source", stage=None, relation="Q")
        self.nodes: List[IRNode] = [self.source]
        self.terminals: List[IRNode] = []
        #: cost layer (``core/cost.py``): a ``CostContext`` once the
        #: planner attaches one; cost-aware passes no-op without it
        self.cost: Optional[Any] = None
        #: autotune pass output: recommended executor/serving knobs
        #: (``n_shards`` / ``max_batch`` / ``max_wait_ms``) with evidence
        self.tuning: Dict[str, Any] = {}

    def _take_id(self) -> int:
        i = self._next_id
        self._next_id += 1
        return i

    def add(self, key: Tuple, kind: str, stage: Transformer,
            inputs: List[IRNode]) -> IRNode:
        node = IRNode(
            id=self._take_id(), key=key, kind=kind, stage=stage,
            inputs=list(inputs),
            relation=_static_relation(kind, stage),
            shardable=bool(getattr(stage, "shardable", True))
            if kind == "stage" else True,
            rank_preserving=bool(getattr(stage, "rank_preserving", False)),
            augment_only=bool(getattr(stage, "augment_only", False)))
        self.nodes.append(node)
        return node

    # -- structural helpers -------------------------------------------------
    def consumers(self) -> Dict[int, List[IRNode]]:
        """node id → nodes consuming it (terminal uses not included)."""
        out: Dict[int, List[IRNode]] = {}
        for node in self.nodes:
            for inp in node.inputs:
                out.setdefault(inp.id, []).append(node)
        return out

    def retopo(self) -> None:
        """Rebuild ``nodes`` as the set reachable from the terminals, in
        topological (inputs-first) order; unreachable nodes are dropped.
        Rewrite passes call this after rewiring edges."""
        order: List[IRNode] = []
        seen = set()

        def visit(node: IRNode) -> None:
            if node.id in seen:
                return
            seen.add(node.id)
            for inp in node.inputs:
                visit(inp)
            order.append(node)

        visit(self.source)
        for t in self.terminals:
            visit(t)
        self.nodes = order

    def n_nodes(self) -> int:
        return len(self.nodes) - 1       # exclude the source


def node_key(kind: str, stage: Optional[Transformer],
             inputs: Sequence[IRNode]) -> Tuple:
    """The canonical structural key for a node — the single source of
    truth for key shapes, used by lowering and by rewrite passes when
    they synthesize nodes or rewire inputs."""
    if kind == "source":
        return ("source",)
    if kind == "combine":
        return ("combine", type(stage).__name__,
                inputs[0].key, inputs[1].key)
    if kind == "scale":
        return ("scale", stage.scalar, inputs[0].key)
    return ("stage", stage.signature(), inputs[0].key)


def make_stage_node(graph: PlanGraph, stage: Transformer,
                    inp: IRNode) -> IRNode:
    """A fresh stage node applied to ``inp`` (shared by lowering and by
    rewrite passes that synthesize nodes, so metadata lifting is uniform)."""
    return graph.add(node_key("stage", stage, [inp]), "stage", stage, [inp])


def lower(pipelines: Sequence[Transformer]) -> PlanGraph:
    """Lower a pipeline set into the logical IR forest.

    One node per syntactic operator occurrence — deduplication is the
    optimizer's job (``core/rewrite.py``), so ``optimize="none"``
    faithfully models naive per-pipeline execution.
    """
    graph = PlanGraph(pipelines)

    def rec(expr: Transformer, inp: IRNode) -> IRNode:
        if isinstance(expr, Compose):
            node = inp
            for stage in expr.stages:
                node = rec(stage, node)
            return node
        if isinstance(expr, _Binary):
            left = rec(expr.left, inp)
            right = rec(expr.right, inp)
            return graph.add(node_key("combine", expr, [left, right]),
                             "combine", expr, [left, right])
        if isinstance(expr, ScalarProduct):
            inner = rec(expr.inner, inp)
            return graph.add(node_key("scale", expr, [inner]),
                             "scale", expr, [inner])
        return make_stage_node(graph, expr, inp)

    graph.terminals = [rec(p, graph.source) for p in pipelines]
    return graph


def plan_size(expr: Transformer) -> int:
    """Stage invocations of one *naive* execution of ``expr`` (binary
    operators expand into 1 + both children, unlike ``stages_of``)."""
    if isinstance(expr, Compose):
        return sum(plan_size(s) for s in expr.stages)
    if isinstance(expr, _Binary):
        return 1 + plan_size(expr.left) + plan_size(expr.right)
    if isinstance(expr, ScalarProduct):
        return 1 + plan_size(expr.inner)
    return 1


# ---------------------------------------------------------------------------
# explain() rendering — shared by ExecutionPlan.explain() and the
# `repro plan explain` CLI, both of which render the *same* plan-manifest
# record, so the two outputs round-trip byte-for-byte.
# ---------------------------------------------------------------------------

def _node_line(rec: Dict[str, Any]) -> str:
    parts = [f"#{rec.get('id')}", f"{rec.get('kind')}:{rec.get('label')}"]
    if rec.get("relation"):
        parts.append(f"[{rec['relation']}]")
    fp = rec.get("fingerprint")
    if fp:
        parts.append(f"fp={str(fp)[:12]}")
    if rec.get("family"):
        cache = rec["family"]
        if rec.get("dir"):
            cache += f"@{rec['dir']}"
        parts.append(f"cache={cache}")
    touched = rec.get("touched_by") or []
    if touched:
        parts.append(f"passes={','.join(touched)}")
    est = rec.get("cost_est_s")
    if est is not None:
        cost = f"cost[est={float(est) * 1e3:.3f}ms"
        act = rec.get("cost_act_s")
        if act is not None:
            cost += f" act={float(act) * 1e3:.3f}ms"
        src = rec.get("cost_src")
        if src:
            cost += f" src={src}"
        parts.append(cost + "]")
    if rec.get("cache_skip"):
        parts.append("(cache-skipped)")
    onl = rec.get("online")
    if onl:
        parts.append("online[p50=%.2fms p99=%.2fms n=%d]"
                     % (onl.get("p50_ms", 0.0), onl.get("p99_ms", 0.0),
                        onl.get("executions", 0)))
    if rec.get("probe_input") is not None:
        parts.append(f"probe=#{rec['probe_input']}")
    if rec.get("inlined"):
        parts.append("(pruned-when-warm)")
    return " ".join(str(p) for p in parts)


def render_explain(record: Dict[str, Any]) -> str:
    """ASCII tree of a plan-manifest record: one tree per pipeline,
    shared nodes printed once and referenced afterwards."""
    nodes = record.get("nodes", [])
    by_id = {n["id"]: n for n in nodes if "id" in n}
    lines: List[str] = []
    opt = record.get("optimizer", {})
    passes = opt.get("passes", [])
    lines.append(f"plan {record.get('plan_id', '?')}: "
                 f"{len(record.get('pipelines', []))} pipeline(s), "
                 f"{len([n for n in nodes if n.get('kind') != 'source'])} "
                 f"node(s)")
    lines.append(f"optimizer: passes={passes or ['(none)']} "
                 f"eliminated={opt.get('nodes_eliminated', 0)} "
                 f"cutoffs_pushed={opt.get('cutoffs_pushed', 0)} "
                 f"prunable={opt.get('nodes_marked_prunable', 0)}")
    seen: set = set()

    def visit(node_id: int, prefix: str, tail: bool) -> None:
        rec = by_id.get(node_id)
        branch = "└─ " if tail else "├─ "
        if rec is None:
            lines.append(prefix + branch + f"#{node_id} <source>")
            return
        if node_id in seen:
            lines.append(prefix + branch +
                         f"#{node_id} {rec.get('label')} (shared, see above)")
            return
        seen.add(node_id)
        lines.append(prefix + branch + _node_line(rec))
        inputs = rec.get("inputs", [])
        ext = "   " if tail else "│  "
        for j, inp in enumerate(inputs):
            visit(inp, prefix + ext, j == len(inputs) - 1)

    terminals = record.get("terminals", [])
    for i, tid in enumerate(terminals):
        pipe = record.get("pipelines", [])
        name = pipe[i] if i < len(pipe) else "?"
        lines.append(f"pipeline[{i}]: {name}")
        visit(tid, "", True)
    return "\n".join(lines)
