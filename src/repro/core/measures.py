"""IR evaluation measures over R (results) × RA (qrels) relations.

Pure-numpy implementations of the standard measures the paper's
``Experiment`` abstraction computes (nDCG@k, MAP, MRR, P@k, R@k,
Judged@k).  Per-query values are returned so the experiment layer can
run significance tests.
"""
from __future__ import annotations

import math
import re
from typing import Callable, Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from .frame import ColFrame

__all__ = ["Measure", "parse_measure", "evaluate", "MEASURES"]


class Measure:
    """A named per-query measure."""

    def __init__(self, name: str, fn: Callable, k: Optional[int] = None):
        self.name = name
        self.fn = fn
        self.k = k

    def __repr__(self):
        return self.name

    def __eq__(self, other):
        return str(other) == self.name

    def __hash__(self):
        return hash(self.name)

    def per_query(self, ranked_docnos: Sequence[str],
                  rels: Mapping[str, float]) -> float:
        return self.fn(ranked_docnos, rels, self.k)


# -- measure bodies ----------------------------------------------------------
# `ranked` = docnos in rank order; `rels` = docno -> graded label (>0 = rel)

def _ndcg(ranked, rels, k):
    k = k or len(ranked)
    gains = [rels.get(d, 0.0) for d in ranked[:k]]
    dcg = sum((2.0 ** g - 1.0) / math.log2(i + 2.0) for i, g in enumerate(gains))
    ideal = sorted(rels.values(), reverse=True)[:k]
    idcg = sum((2.0 ** g - 1.0) / math.log2(i + 2.0) for i, g in enumerate(ideal))
    return dcg / idcg if idcg > 0 else 0.0


def _ap(ranked, rels, k):
    k = k or len(ranked)
    n_rel = sum(1 for v in rels.values() if v > 0)
    if n_rel == 0:
        return 0.0
    hits, s = 0, 0.0
    for i, d in enumerate(ranked[:k]):
        if rels.get(d, 0.0) > 0:
            hits += 1
            s += hits / (i + 1.0)
    return s / n_rel


def _rr(ranked, rels, k):
    k = k or len(ranked)
    for i, d in enumerate(ranked[:k]):
        if rels.get(d, 0.0) > 0:
            return 1.0 / (i + 1.0)
    return 0.0


def _precision(ranked, rels, k):
    k = k or len(ranked)
    if k == 0:
        return 0.0
    return sum(1.0 for d in ranked[:k] if rels.get(d, 0.0) > 0) / float(k)


def _recall(ranked, rels, k):
    k = k or len(ranked)
    n_rel = sum(1 for v in rels.values() if v > 0)
    if n_rel == 0:
        return 0.0
    return sum(1.0 for d in ranked[:k] if rels.get(d, 0.0) > 0) / float(n_rel)


def _judged(ranked, rels, k):
    k = k or len(ranked)
    if k == 0:
        return 0.0
    return sum(1.0 for d in ranked[:k] if d in rels) / float(min(k, max(len(ranked), 1)))


_BASE: Dict[str, Callable] = {
    "nDCG": _ndcg, "AP": _ap, "MAP": _ap, "RR": _rr, "MRR": _rr,
    "P": _precision, "R": _recall, "Recall": _recall, "Judged": _judged,
}

MEASURES = sorted(_BASE)

_MEASURE_RE = re.compile(r"^([A-Za-z]+)(?:@(\d+))?$")


def parse_measure(spec) -> Measure:
    """Parse 'nDCG@10', 'MAP', 'P@5', … into a Measure."""
    if isinstance(spec, Measure):
        return spec
    m = _MEASURE_RE.match(str(spec))
    if not m or m.group(1) not in _BASE:
        raise ValueError(f"unknown measure {spec!r}; known: {MEASURES}")
    name, k = m.group(1), m.group(2)
    return Measure(str(spec), _BASE[name], int(k) if k else None)


# ---------------------------------------------------------------------------

def _qrels_maps(qrels: ColFrame) -> Dict[str, Dict[str, float]]:
    out: Dict[str, Dict[str, float]] = {}
    qid_col = qrels["qid"].tolist()
    doc_col = qrels["docno"].tolist()
    lab_col = qrels["label"].tolist()
    for q, d, l in zip(qid_col, doc_col, lab_col):
        out.setdefault(str(q), {})[str(d)] = float(l)
    return out


def evaluate(results: ColFrame, qrels: ColFrame,
             measures: Sequence) -> Dict[str, Dict[str, float]]:
    """measure-name -> {qid -> value}.  Queries present in qrels but
    retrieved nothing score 0 (trec_eval convention)."""
    measures = [parse_measure(m) for m in measures]
    rel_map = _qrels_maps(qrels)
    per_q: Dict[str, Dict[str, float]] = {m.name: {} for m in measures}

    ranked_by_q: Dict[str, List[str]] = {q: [] for q in rel_map}
    if len(results):
        res = results.sort_values(["qid", "rank"]) if "rank" in results else \
            results.sort_values(["qid", "score"], ascending=[True, False])
        for q, d in zip(res["qid"].tolist(), res["docno"].tolist()):
            q = str(q)
            if q in ranked_by_q:
                ranked_by_q[q].append(str(d))

    for qid, rels in rel_map.items():
        ranked = ranked_by_q.get(qid, [])
        for m in measures:
            per_q[m.name][qid] = m.per_query(ranked, rels)
    return per_q
