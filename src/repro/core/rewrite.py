"""Optimizer passes over the logical plan IR (``core/ir.py``).

The optimizer is an ordered pass pipeline; each pass is a
``(PlanGraph) -> PassStats`` rewrite with its own accounting, selected
through ``ExecutionPlan(optimize=...)``:

* ``"normalize"`` — algebraic normalization: annotates every node with
  a *canonical* structural key in which the operands of commutative
  operators (``a + b`` / ``b + a``, ``a | b`` / ``b | a``) compare
  equal, so CSE can share them.  Annotation-only: no node is rewritten,
  and a lone ``b + a`` keeps its own evaluation order (row order of the
  output frame is only ever affected for expressions that actually get
  merged with a commuted twin).
* ``"cse"`` — cross-pipeline common-subexpression elimination:
  hash-conses the forest bottom-up on (canonical) structural keys, so
  *any* identical subtree — prefix or not, through binary operators —
  executes once.  Strictly generalizes the prefix trie of
  ``precompute.py`` and subsumes the §3 LCP.
* ``"pushdown"`` — ``RankCutoff`` (``% k``) pushdown: a cutoff climbs
  through ``rank_preserving`` single-consumer stages and, when it
  reaches a stage that can absorb it (``Transformer.with_cutoff``,
  e.g. a retriever's ``num_results`` or the dense stage's per-block
  kernel k), is fused away entirely.  Invariant: **pushdown only
  climbs rank-preserving sole-consumer edges** — a shared (multi-
  consumer) node is never rewritten, so pushdown cannot duplicate
  work that CSE shares or deepen another pipeline's view of the node;
  and absorption is sound only because ``with_cutoff`` implementations
  guarantee a deterministic total order (score desc, then doc index),
  making every top-k a prefix of the top-n.
* ``"cache-prune"`` — cache-aware pruning (runs after planner memo
  insertion): consults the provenance manifests (``caching/provenance``)
  of planner-inserted caches and, for memo nodes whose store is warm
  and whose output is assembled purely from the store
  (``serve_from_store``), marks exclusive ``augment_only`` upstream
  stages as *deferred*: the executor probes the cache with the
  upstream chain's input first and only executes the chain on a miss.
  Invariant: only **exclusive, augment-only** upstream chains are
  deferred — augment-only stages cannot alter the keys the memo is
  probed with, and exclusivity guarantees no other consumer observes
  the skipped intermediate.

Three further passes are *cost-aware*: they consume the
:class:`~repro.core.cost.CostContext` the planner attaches as
``graph.cost`` (measured EWMA costs from the plan manifest, roofline
cold-start priors, microbenchmarked cache round-trips) and no-op
without one:

* ``"operand-order"`` — physically orders the operands of commutative
  combines so the expensive subtree is evaluated first, and annotates
  every node with a critical-path ``sched_priority`` the concurrent
  executor uses to dispatch long-pole tasks first.  Guarded to
  rank-preserving-safe cases: only operators declaring
  ``commutative=True`` (whose ``combine`` is symmetric) are reordered,
  and memo digests / provenance fingerprints key off
  commutative-canonical forms, so a swap never cools a warm cache.
* ``"cache-place"`` — skips planner-inserted caches on nodes whose
  estimated recompute is cheaper than the measured backend round-trip
  (a memo there only adds latency and disk), and promotes hot
  expensive nodes on a bare disk backend to a ``tiered:<disk>``
  memory-fronted selector.  Skipping requires *measured or analytic*
  evidence — a default prior never loses a cache — and never fires
  when the round-trip is cheaper than recompute.
* ``"autotune"`` — chooses executor/serving knobs (``n_shards``,
  ``max_batch`` / ``max_wait_ms``) from the manifest's measured run
  history and online batch-occupancy / queue-depth stats, surfaced as
  ``graph.tuning`` / ``ExecutionPlan.tuning()`` and consumed by
  ``serve`` via ``max_batch="auto"``.

Invariant (property-tested): for any pipeline algebra, results with
``optimize="all"`` and ``optimize="none"`` — and with the cost-aware
passes on or off — are bit-identical per qid — same (qid, docno,
score, rank) values under canonical row order — in both the sequential
and the sharded executor.
"""
from __future__ import annotations

import os
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

from .ir import IRNode, PlanGraph, make_stage_node, node_key
from .pipeline import RankCutoff

__all__ = ["PassStats", "OPTIMIZER_PASSES", "PRE_MEMO_PASSES",
           "PLACEMENT_PASSES", "POST_MEMO_PASSES", "resolve_passes",
           "run_pass"]

#: canonical pass order; ``optimize="all"`` runs exactly these.
#: ``operand-order`` always runs after the structural passes (and after
#: the planner's conditional normalize+cse re-round); ``cache-place``
#: runs between the pre passes and memo insertion; the post-memo passes
#: consult freshly opened cache manifests.
PRE_MEMO_PASSES: Tuple[str, ...] = ("normalize", "cse", "pushdown",
                                    "operand-order")
PLACEMENT_PASSES: Tuple[str, ...] = ("cache-place",)
POST_MEMO_PASSES: Tuple[str, ...] = ("cache-prune", "autotune")
OPTIMIZER_PASSES: Tuple[str, ...] = (PRE_MEMO_PASSES + PLACEMENT_PASSES
                                     + POST_MEMO_PASSES)


@dataclass
class PassStats:
    """Per-pass accounting (surfaced via ``PlanStats`` and ``explain()``)."""
    name: str
    nodes_before: int = 0
    nodes_after: int = 0
    nodes_eliminated: int = 0            # removed from the DAG
    cutoffs_pushed: int = 0              # RankCutoffs moved/absorbed
    nodes_marked_prunable: int = 0       # deferred behind a warm cache
    nodes_annotated: int = 0             # normalize: commuted canonical keys
    caches_skipped: int = 0              # cache-place: memos not inserted
    caches_promoted: int = 0             # cache-place: memory-fronted memos
    inputs_reordered: int = 0            # operand-order: swapped operands
    knobs_tuned: int = 0                 # autotune: knobs written
    time_s: float = 0.0

    def as_dict(self) -> Dict:
        return {"name": self.name, "nodes_before": self.nodes_before,
                "nodes_after": self.nodes_after,
                "nodes_eliminated": self.nodes_eliminated,
                "cutoffs_pushed": self.cutoffs_pushed,
                "nodes_marked_prunable": self.nodes_marked_prunable,
                "nodes_annotated": self.nodes_annotated,
                "caches_skipped": self.caches_skipped,
                "caches_promoted": self.caches_promoted,
                "inputs_reordered": self.inputs_reordered,
                "knobs_tuned": self.knobs_tuned,
                "time_s": round(self.time_s, 6)}


def resolve_passes(optimize: Union[str, Sequence[str], None]) -> List[str]:
    """Validate the ``optimize=`` knob into an ordered pass-name list."""
    if optimize in ("all", True):
        return list(OPTIMIZER_PASSES)
    if optimize in ("none", None, False):
        return []
    if isinstance(optimize, str):
        raise ValueError(
            f"optimize must be 'all', 'none' or a list of pass names "
            f"drawn from {list(OPTIMIZER_PASSES)}; got {optimize!r}")
    names = list(optimize)
    for n in names:
        if n not in OPTIMIZER_PASSES:
            raise ValueError(f"unknown optimizer pass {n!r}; "
                             f"known passes: {list(OPTIMIZER_PASSES)}")
    return names


def run_pass(graph: PlanGraph, name: str) -> PassStats:
    """Run one pass by name, returning its stats."""
    fn = {"normalize": _pass_normalize, "cse": _pass_cse,
          "pushdown": _pass_pushdown, "cache-prune": _pass_cache_prune,
          "operand-order": _pass_operand_order,
          "cache-place": _pass_cache_place,
          "autotune": _pass_autotune}[name]
    stats = PassStats(name=name, nodes_before=graph.n_nodes())
    t0 = time.perf_counter()
    fn(graph, stats)
    stats.time_s = time.perf_counter() - t0
    stats.nodes_after = graph.n_nodes()
    return stats


def _touch(node: IRNode, name: str) -> None:
    if name not in node.touched_by:
        node.touched_by.append(name)


# ---------------------------------------------------------------------------
# normalize — commutative-canonical keys
# ---------------------------------------------------------------------------

def _pass_normalize(graph: PlanGraph, stats: PassStats) -> None:
    for node in graph.nodes:
        if node.kind == "source":
            node.canon_key = node.key
            continue
        in_keys = [i.canon_key if i.canon_key is not None else i.key
                   for i in node.inputs]
        if node.kind == "combine":
            ordered = in_keys
            if getattr(node.stage, "commutative", False):
                ordered = sorted(in_keys, key=repr)
                if ordered != in_keys:
                    stats.nodes_annotated += 1
                    _touch(node, "normalize")
            node.canon_key = ("combine", type(node.stage).__name__,
                              *ordered)
        elif node.kind == "scale":
            node.canon_key = ("scale", node.stage.scalar, in_keys[0])
        else:
            node.canon_key = ("stage", node.stage.signature(), in_keys[0])


# ---------------------------------------------------------------------------
# cse — hash-consing on (canonical) keys
# ---------------------------------------------------------------------------

def _pass_cse(graph: PlanGraph, stats: PassStats) -> None:
    seen: Dict[Tuple, IRNode] = {}
    replace: Dict[int, IRNode] = {}
    kept: List[IRNode] = []
    for node in graph.nodes:
        new_inputs = [replace.get(i.id, i) for i in node.inputs]
        if any(n is not o for n, o in zip(new_inputs, node.inputs)):
            node.inputs = new_inputs
            # keep the structural key consistent with the rewired inputs
            node.key = node_key(node.kind, node.stage, node.inputs)
        k = node.canon_key if node.canon_key is not None else node.key
        rep = seen.get(k)
        if rep is None:
            seen[k] = node
            kept.append(node)
        else:
            replace[node.id] = rep
            _touch(rep, "cse")
            stats.nodes_eliminated += 1
    graph.nodes = kept
    graph.terminals = [replace.get(t.id, t) for t in graph.terminals]


# ---------------------------------------------------------------------------
# pushdown — RankCutoff through rank-preserving stages into absorbers
# ---------------------------------------------------------------------------

def _pass_pushdown(graph: PlanGraph, stats: PassStats) -> None:
    # iterate to a fixpoint: absorbing `% 50 % 10` takes two rounds
    while _pushdown_round(graph, stats):
        pass


def _pushdown_round(graph: PlanGraph, stats: PassStats) -> bool:
    consumers = graph.consumers()
    terminal_ids = {t.id for t in graph.terminals}

    def sole_inner(node: IRNode) -> bool:
        """True when ``node`` feeds exactly one consumer and is not a
        pipeline output itself — the only place a rewrite cannot
        duplicate or change shared work."""
        return len(consumers.get(node.id, ())) == 1 \
            and node.id not in terminal_ids

    for node in graph.nodes:
        if node.kind != "stage" or not isinstance(node.stage, RankCutoff):
            continue
        k = node.stage.k
        chain: List[IRNode] = []         # rank-preserving stages, cutoff-down
        cur = node.inputs[0]
        absorber: Optional[IRNode] = None
        absorbed = None
        while cur.kind == "stage" and sole_inner(cur):
            absorbed = cur.stage.with_cutoff(k)
            if absorbed is not None:
                absorber = cur
                break
            if not cur.rank_preserving:
                break
            chain.append(cur)
            cur = cur.inputs[0]
        if absorber is None and not chain:
            continue

        if absorber is not None:
            # fuse the cutoff into the absorber; rebuild the chain on top
            if absorbed is absorber.stage:
                top = absorber           # already <= k results: cutoff no-op
            else:
                top = make_stage_node(graph, absorbed, absorber.inputs[0])
                _touch(top, "pushdown")
            for st in reversed(chain):
                top = make_stage_node(graph, st.stage, top)
                _touch(top, "pushdown")
            replacement = top
            stats.nodes_eliminated += 1  # the cutoff node itself
        else:
            # no absorber: move the cutoff below the rank-preserving
            # chain so downstream stages only see k rows per query
            top = make_stage_node(graph, node.stage, chain[-1].inputs[0])
            _touch(top, "pushdown")
            for st in reversed(chain):
                top = make_stage_node(graph, st.stage, top)
                _touch(top, "pushdown")
            replacement = top
        stats.cutoffs_pushed += 1

        # rewire every consumer of the cutoff (and the terminals) onto
        # the rebuilt chain, then drop unreachable originals
        for consumer in consumers.get(node.id, ()):
            consumer.inputs = [replacement if i is node else i
                               for i in consumer.inputs]
            consumer.key = node_key(consumer.kind, consumer.stage,
                                    consumer.inputs)
        graph.terminals = [replacement if t is node else t
                           for t in graph.terminals]
        graph.retopo()
        return True
    return False


# ---------------------------------------------------------------------------
# cache-prune — defer exclusive augment-only chains behind warm caches
# ---------------------------------------------------------------------------

def _pass_cache_prune(graph: PlanGraph, stats: PassStats) -> None:
    consumers = graph.consumers()
    terminal_ids = {t.id for t in graph.terminals}
    for node in graph.nodes:
        cache = node.cache
        if cache is None or not hasattr(cache, "serve_from_store"):
            continue                     # only store-complete families
        manifest = getattr(cache, "manifest", None)
        if manifest is None or not getattr(manifest, "entry_count", 0):
            continue                     # cold store: nothing to defer to
        key_cols = set(getattr(cache, "key_cols", ()) or ())
        chain: List[IRNode] = []
        cur = node.inputs[0]
        while cur.kind == "stage" and cur.augment_only \
                and cur.cache is None and cur.id not in terminal_ids \
                and len(consumers.get(cur.id, ())) == 1 \
                and not (key_cols & set(
                    getattr(cur.stage, "value_columns", ()) or ())):
            # the last guard: an augment-only stage that *produces* one
            # of the cache's key columns (a query/text attacher) cannot
            # be deferred — the probe frame would lack (or mis-value)
            # that key.  serve_from_store additionally treats a missing
            # key column as a miss, so undeclared producers stay safe.
            chain.append(cur)
            cur = cur.inputs[0]
        if not chain:
            continue
        node.probe_input = cur
        node.inline_chain = list(reversed(chain))   # execution order
        for ch in chain:
            ch.inlined = True
            _touch(ch, "cache-prune")
        _touch(node, "cache-prune")
        stats.nodes_marked_prunable += len(chain)


# ---------------------------------------------------------------------------
# operand-order — expensive subtree first + critical-path priorities
# ---------------------------------------------------------------------------

def _pass_operand_order(graph: PlanGraph, stats: PassStats) -> None:
    cost = graph.cost
    if cost is None:
        return                           # cost-blind compile: no-op
    for node in graph.nodes:
        if node.kind == "source":
            continue
        node.cost_est_s, node.cost_src = cost.estimate(node)
        stats.nodes_annotated += 1

    # 1) physical operand order: evaluate the expensive subtree of a
    #    commutative combine first, so both the sequential executor and
    #    the priority scheduler start the long pole earliest.  Safe only
    #    for operators whose combine() is symmetric (commutative=True);
    #    the 1.2x hysteresis keeps near-ties from flapping run to run.
    swapped = False
    for node in graph.nodes:
        if node.kind != "combine" \
                or not getattr(node.stage, "commutative", False) \
                or len(node.inputs) != 2:
            continue
        a, b = node.inputs
        if cost.subtree_cost(b) > 1.2 * cost.subtree_cost(a):
            node.inputs = [b, a]
            _touch(node, "operand-order")
            stats.inputs_reordered += 1
            swapped = True
    if swapped:
        # structural keys embed input keys: rebuild them topologically
        for node in graph.nodes:
            if node.kind != "source":
                node.key = node_key(node.kind, node.stage, node.inputs)
        cost.invalidate_subtrees()
    # canonical keys must be fresh whenever this pass ran — planner memo
    # digests key off canon_key, which is invariant under the swaps above
    _pass_normalize(graph, PassStats(name="normalize"))

    # 2) critical-path priorities: a node's priority is its own cost
    #    plus the costliest downstream path; the concurrent executor
    #    pops high-priority ready nodes first.  Scheduling metadata
    #    only — results are unaffected.
    consumers = graph.consumers()
    for node in reversed(graph.nodes):   # reverse topological order
        downstream = max(
            (c.sched_priority for c in consumers.get(node.id, ())),
            default=0.0)
        node.sched_priority = (node.cost_est_s or 0.0) + downstream


# ---------------------------------------------------------------------------
# cache-place — skip cheap memos, memory-front hot expensive ones
# ---------------------------------------------------------------------------

def _pass_cache_place(graph: PlanGraph, stats: PassStats) -> None:
    cost = graph.cost
    if cost is None or cost.round_trip_s is None:
        return                           # no caches planned: no-op
    rt = cost.round_trip_s
    for node in graph.nodes:
        if node.kind != "stage":
            continue
        est, src = cost.estimate(node)
        node.cost_est_s, node.cost_src = est, src
        if src == "default":
            continue                     # weak evidence: never lose a cache
        # the alternative to recomputing is the node's cache path.  Its
        # cheapest defensible figure: the microbenchmarked per-entry
        # round trip, tightened by the measured per-query cache-path
        # cost when one exists (min, never max — a cold run's figure is
        # write-heavy and would overstate the steady-state read path,
        # flushing caches that a warm run would have justified)
        cache_s = cost.model.measured_cache_cost(cost.fps.get(node.id))
        alt = rt if cache_s is None else min(rt, cache_s)
        if est * 2.0 < alt:
            # recompute is comfortably cheaper than even the cheapest
            # view of the cache path: a memo here only adds latency and
            # disk.  By construction this cannot fire when the cache
            # path is the cheaper side (alt < est implies est*2 >= alt).
            node.cache_skip = True
            _touch(node, "cache-place")
            stats.caches_skipped += 1
        elif est > 20.0 * rt:
            # hot AND expensive: even the per-entry round trip is worth
            # shaving — front the same persistent store with a memory
            # tier (storage identity is unchanged, dirs stay warm)
            promoted = _promote_selector(cost.backend)
            if promoted is not None:
                node.backend_override = promoted
                _touch(node, "cache-place")
                stats.caches_promoted += 1


def _promote_selector(backend: Optional[str]) -> Optional[str]:
    """``tiered:<disk>`` over a bare persistent disk backend — hot
    expensive nodes get a memory front.  Storage identity is unchanged
    (``caching.backends.storage_identity`` resolves through tiers), so
    warm dirs written by the bare backend stay valid."""
    if not backend:
        return None
    from ..caching.backends import BACKENDS, split_combinator
    if split_combinator(backend) is not None:
        return None                      # already a combinator selector
    cls = BACKENDS.get(backend)
    if cls is None or not cls.persistent:
        return None                      # memory-only: nothing to front
    return f"tiered:{backend}"


# ---------------------------------------------------------------------------
# autotune — executor / serving knobs from measured history
# ---------------------------------------------------------------------------

def _pass_autotune(graph: PlanGraph, stats: PassStats) -> None:
    cost = graph.cost
    if cost is None:
        return
    tuning: Dict[str, Dict[str, Any]] = {}
    history = [r for r in (cost.history or []) if isinstance(r, dict)]

    # -- n_shards: prefer direct evidence (the fastest measured
    #    per-query configuration across prior runs); otherwise estimate
    #    from measured per-node costs.
    by_shards: Dict[int, List[float]] = {}
    for r in history:
        nq = int(r.get("n_queries") or 0)
        wall = r.get("wall_time_s")
        if nq > 0 and isinstance(wall, (int, float)) and wall > 0:
            ns = int(r.get("n_shards") or 1)
            by_shards.setdefault(ns, []).append(float(wall) / nq)
    if len(by_shards) > 1:
        best = min(by_shards, key=lambda ns: min(by_shards[ns]))
        tuning["n_shards"] = {"value": best, "source": "measured-history"}
    else:
        stage_nodes = [n for n in graph.nodes if n.kind == "stage"]
        estimates = [cost.estimate(n) for n in stage_nodes]
        if stage_nodes and all(n.shardable for n in stage_nodes) \
                and any(src == "measured" for _, src in estimates) \
                and sum(est for est, _ in estimates) > 2e-3:
            want = min(8, max(2, os.cpu_count() or 4))
            tuning["n_shards"] = {"value": want, "source": "cost-model"}

    # -- micro-batch knobs from the latest run that carried online
    #    (streaming-executor) stats
    online = next((r["online"] for r in reversed(history)
                   if isinstance(r.get("online"), dict)), None)
    if online:
        occ = float(online.get("batch_occupancy") or 0.0)
        prev_batch = int(online.get("max_batch") or 16)
        if occ >= 0.9:
            batch = min(256, prev_batch * 2)     # saturated: give headroom
        elif 0 < occ < 0.25:
            batch = max(4, prev_batch // 2)      # mostly empty: shrink
        else:
            batch = prev_batch
        tuning["max_batch"] = {"value": batch, "source": "batch-occupancy"}
        wait = float(online.get("max_wait_ms") or 2.0)
        if occ < 0.25 and float(online.get("queue_depth_p99") or 0.0) < 1.0:
            wait = max(0.5, wait / 2.0)          # idle queue: cut latency
        tuning["max_wait_ms"] = {"value": wait, "source": "queue-depth"}

    graph.tuning = tuning
    stats.knobs_tuned = len(tuning)
