"""Optimizer passes over the logical plan IR (``core/ir.py``).

The optimizer is an ordered pass pipeline; each pass is a
``(PlanGraph) -> PassStats`` rewrite with its own accounting, selected
through ``ExecutionPlan(optimize=...)``:

* ``"normalize"`` — algebraic normalization: annotates every node with
  a *canonical* structural key in which the operands of commutative
  operators (``a + b`` / ``b + a``, ``a | b`` / ``b | a``) compare
  equal, so CSE can share them.  Annotation-only: no node is rewritten,
  and a lone ``b + a`` keeps its own evaluation order (row order of the
  output frame is only ever affected for expressions that actually get
  merged with a commuted twin).
* ``"cse"`` — cross-pipeline common-subexpression elimination:
  hash-conses the forest bottom-up on (canonical) structural keys, so
  *any* identical subtree — prefix or not, through binary operators —
  executes once.  Strictly generalizes the prefix trie of
  ``precompute.py`` and subsumes the §3 LCP.
* ``"pushdown"`` — ``RankCutoff`` (``% k``) pushdown: a cutoff climbs
  through ``rank_preserving`` single-consumer stages and, when it
  reaches a stage that can absorb it (``Transformer.with_cutoff``,
  e.g. a retriever's ``num_results`` or the dense stage's per-block
  kernel k), is fused away entirely.  Invariant: **pushdown only
  climbs rank-preserving sole-consumer edges** — a shared (multi-
  consumer) node is never rewritten, so pushdown cannot duplicate
  work that CSE shares or deepen another pipeline's view of the node;
  and absorption is sound only because ``with_cutoff`` implementations
  guarantee a deterministic total order (score desc, then doc index),
  making every top-k a prefix of the top-n.
* ``"cache-prune"`` — cache-aware pruning (runs after planner memo
  insertion): consults the provenance manifests (``caching/provenance``)
  of planner-inserted caches and, for memo nodes whose store is warm
  and whose output is assembled purely from the store
  (``serve_from_store``), marks exclusive ``augment_only`` upstream
  stages as *deferred*: the executor probes the cache with the
  upstream chain's input first and only executes the chain on a miss.
  Invariant: only **exclusive, augment-only** upstream chains are
  deferred — augment-only stages cannot alter the keys the memo is
  probed with, and exclusivity guarantees no other consumer observes
  the skipped intermediate.

Invariant (property-tested): for any pipeline algebra, results with
``optimize="all"`` and ``optimize="none"`` are bit-identical per qid —
same (qid, docno, score, rank) values under canonical row order — in
both the sequential and the sharded executor.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple, Union

from .ir import IRNode, PlanGraph, make_stage_node, node_key
from .pipeline import RankCutoff

__all__ = ["PassStats", "OPTIMIZER_PASSES", "PRE_MEMO_PASSES",
           "POST_MEMO_PASSES", "resolve_passes", "run_pass"]

#: canonical pass order; ``optimize="all"`` runs exactly these
PRE_MEMO_PASSES: Tuple[str, ...] = ("normalize", "cse", "pushdown")
POST_MEMO_PASSES: Tuple[str, ...] = ("cache-prune",)
OPTIMIZER_PASSES: Tuple[str, ...] = PRE_MEMO_PASSES + POST_MEMO_PASSES


@dataclass
class PassStats:
    """Per-pass accounting (surfaced via ``PlanStats`` and ``explain()``)."""
    name: str
    nodes_before: int = 0
    nodes_after: int = 0
    nodes_eliminated: int = 0            # removed from the DAG
    cutoffs_pushed: int = 0              # RankCutoffs moved/absorbed
    nodes_marked_prunable: int = 0       # deferred behind a warm cache
    nodes_annotated: int = 0             # normalize: commuted canonical keys
    time_s: float = 0.0

    def as_dict(self) -> Dict:
        return {"name": self.name, "nodes_before": self.nodes_before,
                "nodes_after": self.nodes_after,
                "nodes_eliminated": self.nodes_eliminated,
                "cutoffs_pushed": self.cutoffs_pushed,
                "nodes_marked_prunable": self.nodes_marked_prunable,
                "nodes_annotated": self.nodes_annotated,
                "time_s": round(self.time_s, 6)}


def resolve_passes(optimize: Union[str, Sequence[str], None]) -> List[str]:
    """Validate the ``optimize=`` knob into an ordered pass-name list."""
    if optimize in ("all", True):
        return list(OPTIMIZER_PASSES)
    if optimize in ("none", None, False):
        return []
    if isinstance(optimize, str):
        raise ValueError(
            f"optimize must be 'all', 'none' or a list of pass names "
            f"drawn from {list(OPTIMIZER_PASSES)}; got {optimize!r}")
    names = list(optimize)
    for n in names:
        if n not in OPTIMIZER_PASSES:
            raise ValueError(f"unknown optimizer pass {n!r}; "
                             f"known passes: {list(OPTIMIZER_PASSES)}")
    return names


def run_pass(graph: PlanGraph, name: str) -> PassStats:
    """Run one pass by name, returning its stats."""
    fn = {"normalize": _pass_normalize, "cse": _pass_cse,
          "pushdown": _pass_pushdown, "cache-prune": _pass_cache_prune}[name]
    stats = PassStats(name=name, nodes_before=graph.n_nodes())
    t0 = time.perf_counter()
    fn(graph, stats)
    stats.time_s = time.perf_counter() - t0
    stats.nodes_after = graph.n_nodes()
    return stats


def _touch(node: IRNode, name: str) -> None:
    if name not in node.touched_by:
        node.touched_by.append(name)


# ---------------------------------------------------------------------------
# normalize — commutative-canonical keys
# ---------------------------------------------------------------------------

def _pass_normalize(graph: PlanGraph, stats: PassStats) -> None:
    for node in graph.nodes:
        if node.kind == "source":
            node.canon_key = node.key
            continue
        in_keys = [i.canon_key if i.canon_key is not None else i.key
                   for i in node.inputs]
        if node.kind == "combine":
            ordered = in_keys
            if getattr(node.stage, "commutative", False):
                ordered = sorted(in_keys, key=repr)
                if ordered != in_keys:
                    stats.nodes_annotated += 1
                    _touch(node, "normalize")
            node.canon_key = ("combine", type(node.stage).__name__,
                              *ordered)
        elif node.kind == "scale":
            node.canon_key = ("scale", node.stage.scalar, in_keys[0])
        else:
            node.canon_key = ("stage", node.stage.signature(), in_keys[0])


# ---------------------------------------------------------------------------
# cse — hash-consing on (canonical) keys
# ---------------------------------------------------------------------------

def _pass_cse(graph: PlanGraph, stats: PassStats) -> None:
    seen: Dict[Tuple, IRNode] = {}
    replace: Dict[int, IRNode] = {}
    kept: List[IRNode] = []
    for node in graph.nodes:
        new_inputs = [replace.get(i.id, i) for i in node.inputs]
        if any(n is not o for n, o in zip(new_inputs, node.inputs)):
            node.inputs = new_inputs
            # keep the structural key consistent with the rewired inputs
            node.key = node_key(node.kind, node.stage, node.inputs)
        k = node.canon_key if node.canon_key is not None else node.key
        rep = seen.get(k)
        if rep is None:
            seen[k] = node
            kept.append(node)
        else:
            replace[node.id] = rep
            _touch(rep, "cse")
            stats.nodes_eliminated += 1
    graph.nodes = kept
    graph.terminals = [replace.get(t.id, t) for t in graph.terminals]


# ---------------------------------------------------------------------------
# pushdown — RankCutoff through rank-preserving stages into absorbers
# ---------------------------------------------------------------------------

def _pass_pushdown(graph: PlanGraph, stats: PassStats) -> None:
    # iterate to a fixpoint: absorbing `% 50 % 10` takes two rounds
    while _pushdown_round(graph, stats):
        pass


def _pushdown_round(graph: PlanGraph, stats: PassStats) -> bool:
    consumers = graph.consumers()
    terminal_ids = {t.id for t in graph.terminals}

    def sole_inner(node: IRNode) -> bool:
        """True when ``node`` feeds exactly one consumer and is not a
        pipeline output itself — the only place a rewrite cannot
        duplicate or change shared work."""
        return len(consumers.get(node.id, ())) == 1 \
            and node.id not in terminal_ids

    for node in graph.nodes:
        if node.kind != "stage" or not isinstance(node.stage, RankCutoff):
            continue
        k = node.stage.k
        chain: List[IRNode] = []         # rank-preserving stages, cutoff-down
        cur = node.inputs[0]
        absorber: Optional[IRNode] = None
        absorbed = None
        while cur.kind == "stage" and sole_inner(cur):
            absorbed = cur.stage.with_cutoff(k)
            if absorbed is not None:
                absorber = cur
                break
            if not cur.rank_preserving:
                break
            chain.append(cur)
            cur = cur.inputs[0]
        if absorber is None and not chain:
            continue

        if absorber is not None:
            # fuse the cutoff into the absorber; rebuild the chain on top
            if absorbed is absorber.stage:
                top = absorber           # already <= k results: cutoff no-op
            else:
                top = make_stage_node(graph, absorbed, absorber.inputs[0])
                _touch(top, "pushdown")
            for st in reversed(chain):
                top = make_stage_node(graph, st.stage, top)
                _touch(top, "pushdown")
            replacement = top
            stats.nodes_eliminated += 1  # the cutoff node itself
        else:
            # no absorber: move the cutoff below the rank-preserving
            # chain so downstream stages only see k rows per query
            top = make_stage_node(graph, node.stage, chain[-1].inputs[0])
            _touch(top, "pushdown")
            for st in reversed(chain):
                top = make_stage_node(graph, st.stage, top)
                _touch(top, "pushdown")
            replacement = top
        stats.cutoffs_pushed += 1

        # rewire every consumer of the cutoff (and the terminals) onto
        # the rebuilt chain, then drop unreachable originals
        for consumer in consumers.get(node.id, ()):
            consumer.inputs = [replacement if i is node else i
                               for i in consumer.inputs]
            consumer.key = node_key(consumer.kind, consumer.stage,
                                    consumer.inputs)
        graph.terminals = [replacement if t is node else t
                           for t in graph.terminals]
        graph.retopo()
        return True
    return False


# ---------------------------------------------------------------------------
# cache-prune — defer exclusive augment-only chains behind warm caches
# ---------------------------------------------------------------------------

def _pass_cache_prune(graph: PlanGraph, stats: PassStats) -> None:
    consumers = graph.consumers()
    terminal_ids = {t.id for t in graph.terminals}
    for node in graph.nodes:
        cache = node.cache
        if cache is None or not hasattr(cache, "serve_from_store"):
            continue                     # only store-complete families
        manifest = getattr(cache, "manifest", None)
        if manifest is None or not getattr(manifest, "entry_count", 0):
            continue                     # cold store: nothing to defer to
        key_cols = set(getattr(cache, "key_cols", ()) or ())
        chain: List[IRNode] = []
        cur = node.inputs[0]
        while cur.kind == "stage" and cur.augment_only \
                and cur.cache is None and cur.id not in terminal_ids \
                and len(consumers.get(cur.id, ())) == 1 \
                and not (key_cols & set(
                    getattr(cur.stage, "value_columns", ()) or ())):
            # the last guard: an augment-only stage that *produces* one
            # of the cache's key columns (a query/text attacher) cannot
            # be deferred — the probe frame would lack (or mis-value)
            # that key.  serve_from_store additionally treats a missing
            # key column as a miss, so undeclared producers stay safe.
            chain.append(cur)
            cur = cur.inputs[0]
        if not chain:
            continue
        node.probe_input = cur
        node.inline_chain = list(reversed(chain))   # execution order
        for ch in chain:
            ch.inlined = True
            _touch(ch, "cache-prune")
        _touch(node, "cache-prune")
        stats.nodes_marked_prunable += len(chain)
