"""Physical executors for optimized plan graphs (``core/ir.py``).

The third layer of the plan compiler: schedulers that evaluate a
:class:`~repro.core.ir.PlanGraph` over a query frame.  Two are
provided, semantics identical (property-tested):

* :func:`run_sequential` — recursive post-order evaluation, one node at
  a time, results memoized per node instance;
* :func:`run_concurrent` — the sharded wavefront scheduler: the query
  frame is partitioned into qid-aligned shards and (node, shard) tasks
  run on a thread pool as their per-shard inputs complete.

Both executors understand the ``cache-prune`` annotations of
``core/rewrite.py``: a node with a ``probe_input`` is evaluated
*lookup-first* — its memo cache is probed with the deferred chain's
input, and the chain (``inline_chain``) only executes when the store
cannot serve every key.  Deferred nodes are excluded from normal
scheduling; they run inline inside their consumer's task.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from concurrent.futures import FIRST_COMPLETED, ThreadPoolExecutor, wait
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from .frame import ColFrame
from .ir import IRNode, PlanGraph
from .precompute import _run_stage

__all__ = ["run_sequential", "run_concurrent", "resolve_n_shards"]


def _qid_runs_unique(qids: np.ndarray) -> bool:
    """True when every qid forms one contiguous run — the property that
    makes cutting at run boundaries preserve per-qid semantics."""
    n = len(qids)
    if n == 0:
        return True
    arr = qids
    if arr.dtype == object or arr.dtype.kind in ("U", "S"):
        arr = arr.astype(str)
    change = np.empty(n, dtype=bool)
    change[0] = True
    change[1:] = arr[1:] != arr[:-1]
    return int(change.sum()) == len(np.unique(arr))


def _shard_bounds(frame: ColFrame, n_shards: int) -> List[Tuple[int, int]]:
    """Partition ``frame`` into ≤ ``n_shards`` contiguous row ranges,
    cutting only at qid-run boundaries so no query straddles a shard."""
    n = len(frame)
    if n == 0 or n_shards <= 1:
        return [(0, n)]
    if "qid" in frame:
        q = frame["qid"]
        arr = q.astype(str) if q.dtype == object or q.dtype.kind in ("U", "S") \
            else q
        cuts = np.nonzero(arr[1:] != arr[:-1])[0] + 1
    else:
        cuts = np.arange(1, n)
    sel: List[int] = []
    prev = 0
    for i in range(1, n_shards):
        target = round(i * n / n_shards)
        j = int(np.searchsorted(cuts, max(target, prev + 1)))
        cands = []
        if j < len(cuts):
            cands.append(int(cuts[j]))
        if j > 0 and int(cuts[j - 1]) > prev:
            cands.append(int(cuts[j - 1]))
        if not cands:
            continue
        c = min(cands, key=lambda x: abs(x - target))
        if prev < c < n:
            sel.append(c)
            prev = c
    bounds = [0] + sel + [n]
    return list(zip(bounds[:-1], bounds[1:]))


def resolve_n_shards(graph: PlanGraph, frame: ColFrame,
                     batch_size: Optional[int],
                     n_shards: Optional[int],
                     max_workers: Optional[int]) -> int:
    n = len(frame)
    if n == 0:
        return 1
    if n_shards is not None:
        want = int(n_shards)
    elif max_workers is not None and int(max_workers) > 1:
        want = -(-n // int(batch_size)) if batch_size else int(max_workers)
    else:
        return 1
    want = max(1, min(want, n))
    if want > 1 and not all(node.shardable for node in graph.nodes
                            if node.kind == "stage"):
        # a stage declared shardable=False (cross-query statistics);
        # partitioning the frame would change its results.  Keep one
        # shard (branch-level parallelism via max_workers still applies).
        return 1
    if want > 1 and "qid" in frame and not _qid_runs_unique(frame["qid"]):
        # a qid with non-contiguous rows cannot be cut without
        # splitting its group; keep one shard
        return 1
    return want


def _exec_node(node: IRNode, ins: List[ColFrame],
               batch_size: Optional[int]) -> ColFrame:
    if node.kind == "stage":
        runner = node.cache if node.cache is not None else node.stage
        if not node.shardable:
            # batching partitions the frame exactly like sharding
            # would — a cross-query stage must see it whole
            return runner(ins[0])
        return _run_stage(runner, ins[0], batch_size)
    if node.kind == "scale":
        return node.stage.apply(ins[0])
    return node.stage.combine(ins[0], ins[1])              # combine


class _Recorder:
    """Thread-safe (label, shard, t0, t1) execution records."""

    def __init__(self) -> None:
        self.records: List[Tuple[str, int, float, float]] = []
        self._lock = threading.Lock()

    def add(self, label: str, shard: int, t0: float, t1: float) -> None:
        with self._lock:
            self.records.append((label, shard, t0, t1))


def _exec_with_probe(node: IRNode, probe_frame: ColFrame,
                     batch_size: Optional[int], shard: int,
                     rec: _Recorder) -> ColFrame:
    """Lookup-first evaluation of a cache-prune annotated node: serve
    from the warm store keyed off ``probe_frame``; on any miss, execute
    the deferred chain to build the node's real input, then run the
    memoized stage normally."""
    t0 = time.perf_counter()
    out = node.cache.serve_from_store(probe_frame)
    if out is not None:
        rec.add(node.label, shard, t0, time.perf_counter())
        return out
    v = probe_frame
    for ch in node.inline_chain:
        t1 = time.perf_counter()
        v = _exec_node(ch, [v], batch_size)
        rec.add(ch.label, shard, t1, time.perf_counter())
    t1 = time.perf_counter()
    out = _exec_node(node, [v], batch_size)
    rec.add(node.label, shard, t1, time.perf_counter())
    return out


def run_sequential(graph: PlanGraph, frame: ColFrame,
                   batch_size: Optional[int],
                   rec: Optional[_Recorder] = None) -> List[ColFrame]:
    """Evaluate all terminals over ``frame``; returns per-pipeline
    results.  Execution records accumulate into ``rec``."""
    rec = rec if rec is not None else _Recorder()
    results: Dict[int, ColFrame] = {graph.source.id: frame}

    def evaluate(node: IRNode) -> ColFrame:
        memo = results.get(node.id)
        if memo is not None:
            return memo
        if node.probe_input is not None and node.cache is not None:
            out = _exec_with_probe(node, evaluate(node.probe_input),
                                   batch_size, 0, rec)
        else:
            ins = [evaluate(i) for i in node.inputs]
            t0 = time.perf_counter()
            out = _exec_node(node, ins, batch_size)
            rec.add(node.label, 0, t0, time.perf_counter())
        results[node.id] = out
        return out

    return [evaluate(t) for t in graph.terminals]


def run_concurrent(graph: PlanGraph, frame: ColFrame,
                   batch_size: Optional[int], n_shards: int, workers: int,
                   rec: _Recorder) -> Tuple[List[ColFrame],
                                            List[Tuple[int, int]]]:
    """Sharded wavefront execution on a thread pool.

    Each (node, shard) pair is one task; a task becomes ready when its
    node's effective inputs have completed *for its shard*, so
    wavefronts advance independently per shard and independent branches
    of one shard run in parallel.  Python-level work holds the GIL, but
    IR stages dominated by I/O, BLAS or accelerator dispatch release it
    — those are exactly the stages worth sharding.

    Returns (per-pipeline merged outputs, shard bounds).
    """
    bounds = _shard_bounds(frame, n_shards)
    n_shards = len(bounds)

    results: Dict[Tuple[int, int], ColFrame] = {}
    for s, (lo, hi) in enumerate(bounds):
        results[(graph.source.id, s)] = frame.take(np.arange(lo, hi))

    def effective_inputs(node: IRNode) -> List[IRNode]:
        # cache-prune: a probing node waits on the chain's *input*; the
        # deferred chain itself runs inline inside this node's task
        if node.probe_input is not None and node.cache is not None:
            return [node.probe_input]
        return node.inputs

    schedulable = [n for n in graph.nodes
                   if n.kind != "source" and not n.inlined]
    children: Dict[int, List[IRNode]] = {}
    indeg: Dict[Tuple[int, int], int] = {}
    for node in schedulable:
        eff = effective_inputs(node)
        for inp in eff:
            children.setdefault(inp.id, []).append(node)
        for s in range(n_shards):
            indeg[(node.id, s)] = len(eff)

    ready: deque = deque()

    def complete(node_id: int, s: int) -> None:
        for child in children.get(node_id, ()):
            key = (child.id, s)
            indeg[key] -= 1
            if indeg[key] == 0:
                ready.append((child, s))

    for s in range(n_shards):
        complete(graph.source.id, s)

    def exec_task(node: IRNode, s: int) -> None:
        if node.probe_input is not None and node.cache is not None:
            out = _exec_with_probe(node, results[(node.probe_input.id, s)],
                                   batch_size, s, rec)
        else:
            ins = [results[(i.id, s)] for i in node.inputs]
            t0 = time.perf_counter()
            out = _exec_node(node, ins, batch_size)
            rec.add(node.label, s, t0, time.perf_counter())
        results[(node.id, s)] = out

    with ThreadPoolExecutor(max_workers=workers) as pool:
        futures: Dict[Any, Tuple[IRNode, int]] = {}

        def submit_ready() -> None:
            while ready:
                node, s = ready.popleft()
                fut = pool.submit(exec_task, node, s)
                futures[fut] = (node, s)

        submit_ready()
        while futures:
            done, _ = wait(set(futures), return_when=FIRST_COMPLETED)
            for fut in done:
                node, s = futures.pop(fut)
                fut.result()                 # propagate task errors
                complete(node.id, s)
            submit_ready()

    outs = [ColFrame.concat([results[(t.id, s)] for s in range(n_shards)])
            for t in graph.terminals]
    return outs, bounds
