"""Physical executors for optimized plan graphs (``core/ir.py``).

The third layer of the plan compiler: schedulers that evaluate a
:class:`~repro.core.ir.PlanGraph` over a query frame.  Three are
provided; the offline two have identical semantics (property-tested):

* :func:`run_sequential` — recursive post-order evaluation, one node at
  a time, results memoized per node instance;
* :func:`run_concurrent` — the sharded wavefront scheduler: the query
  frame is partitioned into qid-aligned shards and (node, shard) tasks
  run on a thread pool as their per-shard inputs complete;
* :class:`StreamingExecutor` — the *online* mode: long-lived, fed by
  concurrent request submissions that coalesce into micro-batches
  (bounded queue, flush on ``max_batch`` or ``max_wait_ms``), each
  flowing through the same DAG wavefront machinery as the offline
  scheduler — a micro-batch takes the structural place of a shard, so
  several batches can be in flight at different depths of the DAG.

All executors understand the ``cache-prune`` annotations of
``core/rewrite.py``: a node with a ``probe_input`` is evaluated
*lookup-first* — its memo cache is probed with the deferred chain's
input, and the chain (``inline_chain``) only executes when the store
cannot serve every key.  Deferred nodes are excluded from normal
scheduling; they run inline inside their consumer's task.

Scheduling invariants: every node runs **at most once per shard**
(results are memoized per node instance, never recomputed for a second
consumer); tasks are dispatched in **topological wavefronts**, so a
node's inputs are complete frames before it runs; and the query frame
is partitioned only along **qid-aligned boundaries** and only when
every stage in the graph is ``shardable`` (row-local per qid) — a
single non-shardable stage collapses execution to one shard, leaving
branch parallelism only.  Under these rules the sequential and
concurrent schedulers are observationally identical (property-tested
in ``tests/test_rewrite.py``).
"""
from __future__ import annotations

import heapq
import queue as queue_mod
import random
import threading
import time
from collections import deque
from concurrent.futures import FIRST_COMPLETED, Future, ThreadPoolExecutor, wait
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .frame import ColFrame
from .ir import IRNode, PlanGraph
from .precompute import _run_stage

__all__ = ["run_sequential", "run_concurrent", "run_warm",
           "resolve_n_shards", "Reservoir", "NodeOnlineStats",
           "StreamStats", "StreamingExecutor"]


def _qid_runs_unique(qids: np.ndarray) -> bool:
    """True when every qid forms one contiguous run — the property that
    makes cutting at run boundaries preserve per-qid semantics."""
    n = len(qids)
    if n == 0:
        return True
    arr = qids
    if arr.dtype == object or arr.dtype.kind in ("U", "S"):
        arr = arr.astype(str)
    change = np.empty(n, dtype=bool)
    change[0] = True
    change[1:] = arr[1:] != arr[:-1]
    return int(change.sum()) == len(np.unique(arr))


def _shard_bounds(frame: ColFrame, n_shards: int) -> List[Tuple[int, int]]:
    """Partition ``frame`` into ≤ ``n_shards`` contiguous row ranges,
    cutting only at qid-run boundaries so no query straddles a shard."""
    n = len(frame)
    if n == 0 or n_shards <= 1:
        return [(0, n)]
    if "qid" in frame:
        q = frame["qid"]
        arr = q.astype(str) if q.dtype == object or q.dtype.kind in ("U", "S") \
            else q
        cuts = np.nonzero(arr[1:] != arr[:-1])[0] + 1
    else:
        cuts = np.arange(1, n)
    sel: List[int] = []
    prev = 0
    for i in range(1, n_shards):
        target = round(i * n / n_shards)
        j = int(np.searchsorted(cuts, max(target, prev + 1)))
        cands = []
        if j < len(cuts):
            cands.append(int(cuts[j]))
        if j > 0 and int(cuts[j - 1]) > prev:
            cands.append(int(cuts[j - 1]))
        if not cands:
            continue
        c = min(cands, key=lambda x: abs(x - target))
        if prev < c < n:
            sel.append(c)
            prev = c
    bounds = [0] + sel + [n]
    return list(zip(bounds[:-1], bounds[1:]))


def resolve_n_shards(graph: PlanGraph, frame: ColFrame,
                     batch_size: Optional[int],
                     n_shards: Optional[int],
                     max_workers: Optional[int]) -> int:
    n = len(frame)
    if n == 0:
        return 1
    if n_shards is not None:
        want = int(n_shards)
    elif max_workers is not None and int(max_workers) > 1:
        want = -(-n // int(batch_size)) if batch_size else int(max_workers)
    else:
        return 1
    want = max(1, min(want, n))
    if want > 1 and not all(node.shardable for node in graph.nodes
                            if node.kind == "stage"):
        # a stage declared shardable=False (cross-query statistics);
        # partitioning the frame would change its results.  Keep one
        # shard (branch-level parallelism via max_workers still applies).
        return 1
    if want > 1 and "qid" in frame and not _qid_runs_unique(frame["qid"]):
        # a qid with non-contiguous rows cannot be cut without
        # splitting its group; keep one shard
        return 1
    return want


def _exec_node(node: IRNode, ins: List[ColFrame],
               batch_size: Optional[int]) -> ColFrame:
    if node.kind == "stage":
        runner = node.cache if node.cache is not None else node.stage
        if not node.shardable:
            # batching partitions the frame exactly like sharding
            # would — a cross-query stage must see it whole
            return runner(ins[0])
        return _run_stage(runner, ins[0], batch_size)
    if node.kind == "scale":
        return node.stage.apply(ins[0])
    return node.stage.combine(ins[0], ins[1])              # combine


class _Recorder:
    """Thread-safe (label, shard, t0, t1) execution records."""

    def __init__(self) -> None:
        self.records: List[Tuple[str, int, float, float]] = []
        self._lock = threading.Lock()

    def add(self, label: str, shard: int, t0: float, t1: float) -> None:
        with self._lock:
            self.records.append((label, shard, t0, t1))


class _NullRecorder(_Recorder):
    """Drops records — the streaming executor keeps bounded per-node
    reservoirs instead of an ever-growing record list."""

    def add(self, label: str, shard: int, t0: float, t1: float) -> None:
        pass


_NULL_RECORDER = _NullRecorder()


def _effective_inputs(node: IRNode) -> List[IRNode]:
    """The inputs a scheduler must wait for.  Cache-prune: a probing
    node waits on the deferred chain's *input*; the chain itself runs
    inline inside this node's task."""
    if node.probe_input is not None and node.cache is not None:
        return [node.probe_input]
    return node.inputs


def _wave_edges(graph: PlanGraph
                ) -> Tuple[List[IRNode], Dict[int, List[IRNode]]]:
    """(schedulable nodes, input-id → consumers) — the wavefront edge
    structure shared by the offline sharded scheduler and the streaming
    executor.  Nodes are addressed by instance id throughout."""
    schedulable = [n for n in graph.nodes
                   if n.kind != "source" and not n.inlined]
    children: Dict[int, List[IRNode]] = {}
    for node in schedulable:
        for inp in _effective_inputs(node):
            children.setdefault(inp.id, []).append(node)
    return schedulable, children


def _exec_with_probe(node: IRNode, probe_frame: ColFrame,
                     batch_size: Optional[int], shard: int,
                     rec: _Recorder) -> ColFrame:
    """Lookup-first evaluation of a cache-prune annotated node: serve
    from the warm store keyed off ``probe_frame``; on any miss, execute
    the deferred chain to build the node's real input, then run the
    memoized stage normally."""
    t0 = time.perf_counter()
    out = node.cache.serve_from_store(probe_frame)
    if out is not None:
        rec.add(node.label, shard, t0, time.perf_counter())
        return out
    v = probe_frame
    for ch in node.inline_chain:
        t1 = time.perf_counter()
        v = _exec_node(ch, [v], batch_size)
        rec.add(ch.label, shard, t1, time.perf_counter())
    t1 = time.perf_counter()
    out = _exec_node(node, [v], batch_size)
    rec.add(node.label, shard, t1, time.perf_counter())
    return out


class _Prefetcher:
    """Issues cache ``get_many`` calls on the I/O pool the moment a
    node's keys are knowable, for every plan node stamped ``prefetch``.

    A cache's keys derive from the frame its node consumes
    (``prefetch_columns``), so the fetch can start when that *feeding*
    node completes: for query-keyed families fed by the source
    (retrievers, probe nodes) that is submit time — the reads overlap
    wave-0 compute — and for doc-keyed families (``ScorerCache``) it is
    the upstream retriever's completion, overlapping sibling branches.
    The executors call :meth:`node_ready` for the source and after
    every node; the mapping here decides which caches that feeds.

    Results land in each cache's staging map; the consuming
    ``transform``/``serve_from_store`` pops them, so accounting and
    compute-once semantics are untouched (see ``caching/dataplane.py``).
    """

    def __init__(self, graph: PlanGraph):
        #: feeding-node id → [(consumer node, its cache)]
        self._by_feed: Dict[int, List[Tuple[IRNode, Any]]] = {}
        for node in graph.nodes:
            if node.kind != "stage" or node.inlined or not node.prefetch:
                continue
            cache = node.cache
            if cache is None or not getattr(cache, "prefetchable", False):
                continue
            cols = cache.prefetch_columns() \
                if hasattr(cache, "prefetch_columns") else None
            if not cols:
                continue
            feeds = _effective_inputs(node)
            if len(feeds) != 1:
                continue
            self._by_feed.setdefault(feeds[0].id, []).append((node, cache))

    @classmethod
    def for_graph(cls, graph: PlanGraph) -> Optional["_Prefetcher"]:
        pf = cls(graph)
        return pf if pf._by_feed else None

    def node_ready(self, node_id: int, frame: ColFrame) -> None:
        """``node_id``'s output exists — start fetching for every cache
        it feeds whose key columns the frame carries.  Pass the source
        id at submit time to kick off query-keyed prefetches."""
        for _, cache in self._by_feed.get(node_id, ()):
            cols = cache.prefetch_columns()
            if cols and all(c in frame for c in cols):
                try:
                    cache.prefetch_async(frame)
                except Exception:
                    pass                 # a failed prefetch is a non-fetch

    def close(self) -> None:
        """Run teardown: drop staged entries nobody consumed."""
        for entries in self._by_feed.values():
            for _, cache in entries:
                try:
                    cache.discard_staging()
                except Exception:
                    pass


def run_sequential(graph: PlanGraph, frame: ColFrame,
                   batch_size: Optional[int],
                   rec: Optional[_Recorder] = None) -> List[ColFrame]:
    """Evaluate all terminals over ``frame``; returns per-pipeline
    results.  Execution records accumulate into ``rec``."""
    rec = rec if rec is not None else _Recorder()
    results: Dict[int, ColFrame] = {graph.source.id: frame}
    pf = _Prefetcher.for_graph(graph)

    def evaluate(node: IRNode) -> ColFrame:
        memo = results.get(node.id)
        if memo is not None:
            return memo
        if node.probe_input is not None and node.cache is not None:
            out = _exec_with_probe(node, evaluate(node.probe_input),
                                   batch_size, 0, rec)
        else:
            ins = [evaluate(i) for i in node.inputs]
            t0 = time.perf_counter()
            out = _exec_node(node, ins, batch_size)
            rec.add(node.label, 0, t0, time.perf_counter())
        results[node.id] = out
        if pf is not None:
            pf.node_ready(node.id, out)
        return out

    try:
        if pf is not None:
            # query-keyed prefetches start before any compute: sibling
            # pipelines' store reads overlap the first chain's work
            pf.node_ready(graph.source.id, frame)
        return [evaluate(t) for t in graph.terminals]
    finally:
        if pf is not None:
            pf.close()


def run_warm(graph: PlanGraph, frame: ColFrame,
             batch_size: Optional[int] = None, *,
             chunk_rows: Optional[int] = None,
             rec: Optional[_Recorder] = None) -> int:
    """Offline cache warming: evaluate every terminal over ``frame``
    purely for the side effect of populating memo caches; outputs are
    discarded chunk by chunk.

    With ``chunk_rows``, the frame is cut into qid-aligned chunks of
    roughly that many rows (the same boundary logic as the sharded
    scheduler), so warming an arbitrarily large query log holds at most
    one chunk of intermediates in memory.  Chunking is skipped — one
    full pass — when a stage declares ``shardable=False`` or qid runs
    are non-contiguous, exactly mirroring ``resolve_n_shards``.
    Returns the number of chunks executed.
    """
    rec = rec if rec is not None else _Recorder()
    n = len(frame)
    if n == 0:
        return 0
    bounds = [(0, n)]
    if chunk_rows is not None and 0 < int(chunk_rows) < n:
        want = -(-n // int(chunk_rows))
        if all(node.shardable for node in graph.nodes
               if node.kind == "stage") \
                and ("qid" not in frame
                     or _qid_runs_unique(frame["qid"])):
            bounds = _shard_bounds(frame, want)
    for lo, hi in bounds:
        chunk = frame if (lo, hi) == (0, n) \
            else frame.take(np.arange(lo, hi))
        run_sequential(graph, chunk, batch_size, rec)
    return len(bounds)


def run_concurrent(graph: PlanGraph, frame: ColFrame,
                   batch_size: Optional[int], n_shards: int, workers: int,
                   rec: _Recorder) -> Tuple[List[ColFrame],
                                            List[Tuple[int, int]]]:
    """Sharded wavefront execution on a thread pool.

    Each (node, shard) pair is one task; a task becomes ready when its
    node's effective inputs have completed *for its shard*, so
    wavefronts advance independently per shard and independent branches
    of one shard run in parallel.  Python-level work holds the GIL, but
    IR stages dominated by I/O, BLAS or accelerator dispatch release it
    — those are exactly the stages worth sharding.

    Returns (per-pipeline merged outputs, shard bounds).
    """
    bounds = _shard_bounds(frame, n_shards)
    n_shards = len(bounds)
    pf = _Prefetcher.for_graph(graph)

    results: Dict[Tuple[int, int], ColFrame] = {}
    for s, (lo, hi) in enumerate(bounds):
        shard = frame.take(np.arange(lo, hi))
        results[(graph.source.id, s)] = shard
        if pf is not None:
            # per-shard query-keyed prefetch at submit time, before any
            # task is scheduled — the store reads overlap wave 0
            pf.node_ready(graph.source.id, shard)

    schedulable, children = _wave_edges(graph)
    indeg: Dict[Tuple[int, int], int] = {}
    for node in schedulable:
        for s in range(n_shards):
            indeg[(node.id, s)] = len(_effective_inputs(node))

    # ready tasks pop in critical-path order: the operand-order pass
    # stamps each node's sched_priority with its own estimated cost plus
    # the costliest downstream path, so when more tasks are ready than
    # workers the long pole starts first.  The monotone sequence number
    # keeps equal-priority tasks FIFO (and, with priorities all zero —
    # the cost-blind default — reduces to the previous deque order).
    ready: List[Tuple[float, int, IRNode, int]] = []
    seq = 0

    def complete(node_id: int, s: int) -> None:
        nonlocal seq
        for child in children.get(node_id, ()):
            key = (child.id, s)
            indeg[key] -= 1
            if indeg[key] == 0:
                heapq.heappush(ready,
                               (-child.sched_priority, seq, child, s))
                seq += 1

    for s in range(n_shards):
        complete(graph.source.id, s)

    def exec_task(node: IRNode, s: int) -> None:
        if node.probe_input is not None and node.cache is not None:
            out = _exec_with_probe(node, results[(node.probe_input.id, s)],
                                   batch_size, s, rec)
        else:
            ins = [results[(i.id, s)] for i in node.inputs]
            t0 = time.perf_counter()
            out = _exec_node(node, ins, batch_size)
            rec.add(node.label, s, t0, time.perf_counter())
        results[(node.id, s)] = out

    try:
        with ThreadPoolExecutor(max_workers=workers) as pool:
            futures: Dict[Any, Tuple[IRNode, int]] = {}

            def submit_ready() -> None:
                while ready:
                    _, _, node, s = heapq.heappop(ready)
                    fut = pool.submit(exec_task, node, s)
                    futures[fut] = (node, s)

            submit_ready()
            while futures:
                done, _ = wait(set(futures), return_when=FIRST_COMPLETED)
                for fut in done:
                    node, s = futures.pop(fut)
                    fut.result()                 # propagate task errors
                    if pf is not None:
                        # doc-keyed consumers of this node start their
                        # store reads now, overlapping sibling branches
                        pf.node_ready(node.id, results[(node.id, s)])
                    complete(node.id, s)
                submit_ready()
    finally:
        if pf is not None:
            pf.close()

    outs = [ColFrame.concat([results[(t.id, s)] for s in range(n_shards)])
            for t in graph.terminals]
    return outs, bounds


# ---------------------------------------------------------------------------
# online / incremental mode — micro-batched streaming execution
# ---------------------------------------------------------------------------

class Reservoir:
    """Bounded, thread-safe reservoir sample of a float stream.

    Fixes the unbounded-growth failure mode of keeping every latency in
    a list: memory is capped at ``capacity`` floats while percentiles
    stay estimates of the *whole* stream (Algorithm R, deterministic
    RNG so repeated runs are reproducible)."""

    __slots__ = ("capacity", "count", "_buf", "_rng", "_lock")

    def __init__(self, capacity: int = 2048, seed: int = 0):
        self.capacity = max(1, int(capacity))
        self.count = 0
        self._buf: List[float] = []
        self._rng = random.Random(seed)
        self._lock = threading.Lock()

    def add(self, value: float) -> None:
        with self._lock:
            self.count += 1
            if len(self._buf) < self.capacity:
                self._buf.append(float(value))
            else:
                j = self._rng.randrange(self.count)
                if j < self.capacity:
                    self._buf[j] = float(value)

    def extend(self, values: Sequence[float]) -> None:
        for v in values:
            self.add(v)

    def percentile(self, p: float) -> float:
        with self._lock:
            return float(np.percentile(self._buf, p)) if self._buf else 0.0

    @property
    def mean(self) -> float:
        with self._lock:
            return float(np.mean(self._buf)) if self._buf else 0.0

    @property
    def max(self) -> float:
        with self._lock:
            return float(np.max(self._buf)) if self._buf else 0.0

    def snapshot(self) -> List[float]:
        with self._lock:
            return list(self._buf)

    def __len__(self) -> int:
        with self._lock:
            return len(self._buf)


class NodeOnlineStats:
    """Per-node accounting of the streaming executor: execution count,
    rows processed, and a bounded latency reservoir."""

    __slots__ = ("executions", "rows", "latency_ms", "_lock")

    def __init__(self) -> None:
        self.executions = 0
        self.rows = 0
        self.latency_ms = Reservoir(1024)
        self._lock = threading.Lock()

    def record(self, dt_ms: float, rows: int) -> None:
        with self._lock:
            self.executions += 1
            self.rows += int(rows)
        self.latency_ms.add(dt_ms)

    def as_dict(self) -> Dict[str, float]:
        return {"executions": self.executions, "rows": self.rows,
                "p50_ms": round(self.latency_ms.percentile(50), 4),
                "p99_ms": round(self.latency_ms.percentile(99), 4)}


class StreamStats:
    """Service-level accounting of the streaming executor: flush
    triggers, queue depth, micro-batch occupancy, per-node online
    latency, and cache hit/miss totals built from *per-call* counts."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self.requests = 0
        self.batches = 0
        self.rows_in = 0                 # rows submitted (pre-coalesce)
        self.rows_executed = 0           # unique rows after coalescing
        self.flush_size = 0              # dispatches triggered by max_batch
        self.flush_timeout = 0           # ... by max_wait_ms
        self.flush_forced = 0            # ... by flush()/close()
        self.cache_hits = 0
        self.cache_misses = 0
        self.queue_depth = Reservoir(1024)
        self.batch_requests = Reservoir(1024)
        self.nodes: Dict[str, NodeOnlineStats] = {}

    def node(self, label: str) -> NodeOnlineStats:
        with self._lock:
            ns = self.nodes.get(label)
            if ns is None:
                ns = self.nodes[label] = NodeOnlineStats()
            return ns

    def record_batch(self, *, n_requests: int, rows_in: int,
                     rows_executed: int, cause: str) -> None:
        with self._lock:
            self.requests += n_requests
            self.batches += 1
            self.rows_in += rows_in
            self.rows_executed += rows_executed
            if cause == "size":
                self.flush_size += 1
            elif cause == "timeout":
                self.flush_timeout += 1
            else:
                self.flush_forced += 1
        self.batch_requests.add(n_requests)

    def add_cache_counts(self, hits: int, misses: int) -> None:
        if hits or misses:
            with self._lock:
                self.cache_hits += hits
                self.cache_misses += misses

    def occupancy(self, max_batch: int) -> float:
        """Mean micro-batch fill: requests per dispatch / ``max_batch``."""
        return self.batch_requests.mean / max(1, max_batch)

    def node_dicts(self) -> Dict[str, Dict[str, float]]:
        with self._lock:
            labels = list(self.nodes.items())
        return {label: ns.as_dict() for label, ns in labels}

    def as_dict(self, max_batch: Optional[int] = None) -> Dict[str, Any]:
        out = {
            "requests": self.requests, "batches": self.batches,
            "rows_in": self.rows_in, "rows_executed": self.rows_executed,
            "flush_size": self.flush_size,
            "flush_timeout": self.flush_timeout,
            "flush_forced": self.flush_forced,
            "cache_hits": self.cache_hits,
            "cache_misses": self.cache_misses,
            "queue_depth_p50": round(self.queue_depth.percentile(50), 2),
            "queue_depth_p99": round(self.queue_depth.percentile(99), 2),
            "queue_depth_max": round(self.queue_depth.max, 2),
            "nodes": self.node_dicts(),
        }
        if max_batch is not None:
            out["batch_occupancy"] = round(self.occupancy(max_batch), 4)
        return out


def _freeze_value(v: Any) -> Any:
    """A hashable, reliably-comparable stand-in for a row value — row
    identity drives coalescing, and raw numpy arrays would make the
    tuple comparison raise ('truth value of an array is ambiguous')."""
    if isinstance(v, np.ndarray):
        return ("__ndarray__", v.shape, str(v.dtype), v.tobytes())
    if isinstance(v, (list, tuple)):
        return tuple(_freeze_value(x) for x in v)
    if isinstance(v, dict):
        return tuple(sorted((k, _freeze_value(x)) for k, x in v.items()))
    if isinstance(v, np.generic):
        return v.item()
    return v


class _StreamRequest:
    __slots__ = ("rows", "qid_rows", "qid_orig", "qid_order", "future",
                 "t0")

    def __init__(self, rows: List[Dict[str, Any]]):
        self.rows = rows
        # per-qid: a frozen content key (drives coalescing comparisons)
        # plus the ORIGINAL rows (what actually executes); first-seen
        # qid order preserved
        self.qid_rows: Dict[str, Tuple] = {}
        self.qid_orig: Dict[str, List[Dict[str, Any]]] = {}
        self.qid_order: List[str] = []
        for r in rows:
            q = str(r.get("qid"))
            frozen = tuple(sorted((k, _freeze_value(v))
                                  for k, v in r.items()))
            if q not in self.qid_rows:
                self.qid_rows[q] = (frozen,)
                self.qid_orig[q] = [r]
                self.qid_order.append(q)
            else:
                self.qid_rows[q] = self.qid_rows[q] + (frozen,)
                self.qid_orig[q].append(r)
        self.future: Future = Future()
        self.t0 = time.perf_counter()


class _BatchMeta:
    __slots__ = ("requests", "cause", "n_rows_in", "failed",
                 "hits", "misses")

    def __init__(self, requests: List[_StreamRequest], cause: str,
                 n_rows_in: int):
        self.requests = requests
        self.cause = cause
        self.n_rows_in = n_rows_in
        self.failed = False
        self.hits = 0
        self.misses = 0


_STOP = object()
_FLUSH = object()


class StreamingExecutor:
    """Incremental wavefront scheduler for online serving.

    Long-lived: a dispatcher thread drains a bounded request queue into
    micro-batches — a batch closes when ``max_batch`` requests are
    waiting, when ``max_wait_ms`` has elapsed since its first request,
    or on an explicit :meth:`flush`.  Requests in one batch are
    *coalesced* per qid (N in-flight requests sharing a query execute
    its rows once; every requester gets the result), the unique rows
    execute as ONE frame through the DAG, and the terminal output is
    demultiplexed back onto the request futures by qid.

    The wavefront machinery (``_wave_edges`` / instance-id addressing /
    probe-first cache-prune evaluation) is shared with the offline
    sharded scheduler: a micro-batch occupies the structural slot of a
    shard, so while batch *k* is in the reranker, batch *k+1* can
    already be in the retriever on the same thread pool.

    Correctness relies on the same row-local-per-qid contract as
    sharding (``Transformer.shardable``): when any stage declares
    ``shardable=False``, requests are NOT coalesced across submissions
    — each request executes as its own single-request batch.
    """

    def __init__(self, graph: PlanGraph, *, batch_size: Optional[int] = None,
                 max_batch: int = 32, max_wait_ms: float = 2.0,
                 max_workers: int = 4, queue_capacity: int = 1024,
                 on_batch: Optional[Callable[..., None]] = None):
        if len(graph.terminals) != 1:
            raise ValueError(
                f"StreamingExecutor serves exactly one pipeline; the plan "
                f"has {len(graph.terminals)} terminals")
        self.graph = graph
        self.terminal = graph.terminals[0]
        self._schedulable, self._children = _wave_edges(graph)
        self._prefetcher = _Prefetcher.for_graph(graph)
        self.coalescing = all(n.shardable for n in graph.nodes
                              if n.kind == "stage")
        self.batch_size = batch_size
        self.max_batch = max(1, int(max_batch))
        self.max_wait_s = max(0.0, float(max_wait_ms) / 1000.0)
        self.stats = StreamStats()
        self._on_batch = on_batch
        self._queue: "queue_mod.Queue" = queue_mod.Queue(
            maxsize=max(1, int(queue_capacity)))
        # serializes enqueue against close(): nothing can land behind
        # the _STOP sentinel, so no future is ever left pending
        self._submit_lock = threading.Lock()
        self._pool = ThreadPoolExecutor(
            max_workers=max(1, int(max_workers)),
            thread_name_prefix="repro-serve")
        self._lock = threading.Lock()
        self._idle = threading.Condition(self._lock)
        self._results: Dict[Tuple[int, int], ColFrame] = {}
        self._indeg: Dict[Tuple[int, int], int] = {}
        self._meta: Dict[int, _BatchMeta] = {}
        self._seq = 0
        self._inflight = 0
        self._closed = False
        self._dispatcher = threading.Thread(
            target=self._dispatch_loop, name="repro-serve-dispatch",
            daemon=True)
        self._dispatcher.start()

    # -- client API ----------------------------------------------------------
    def submit(self, rows: List[Dict[str, Any]]) -> Future:
        """Enqueue one request (one or more query rows, each carrying a
        ``qid``).  Returns a future resolving to the pipeline output for
        those rows.  Blocks (backpressure) when the queue is full."""
        if not rows:
            fut: Future = Future()
            fut.set_result(ColFrame())
            return fut
        for r in rows:
            if "qid" not in r:
                raise ValueError("every request row needs a 'qid'")
        req = _StreamRequest([dict(r) for r in rows])
        with self._submit_lock:
            if self._closed:
                raise RuntimeError("StreamingExecutor is closed")
            self._queue.put(req)
        self.stats.queue_depth.add(self._queue.qsize())
        return req.future

    def flush(self) -> None:
        """Dispatch whatever is queued without waiting for the batch
        window to fill or expire."""
        with self._submit_lock:
            if not self._closed:
                self._queue.put(_FLUSH)

    def close(self, timeout: float = 60.0) -> None:
        """Dispatch remaining requests, wait for in-flight batches, and
        shut the pool down."""
        with self._submit_lock:
            if self._closed:
                return
            self._closed = True
            self._queue.put(_STOP)
        self._dispatcher.join(timeout=timeout)
        with self._idle:
            self._idle.wait_for(lambda: self._inflight == 0,
                                timeout=timeout)
        self._pool.shutdown(wait=True)
        if self._prefetcher is not None:
            self._prefetcher.close()

    def __enter__(self) -> "StreamingExecutor":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False

    # -- dispatcher ----------------------------------------------------------
    def _dispatch_loop(self) -> None:
        while True:
            item = self._queue.get()
            if item is _STOP:
                return
            if item is _FLUSH:
                continue
            batch: List[_StreamRequest] = [item]
            cause = "size"
            stop = False
            deadline = time.monotonic() + self.max_wait_s
            while len(batch) < self.max_batch:
                remaining = deadline - time.monotonic()
                try:
                    # window expired (or max_wait_ms=0): drain whatever
                    # is already queued without waiting, so backlogged
                    # submissions still coalesce into one batch
                    nxt = self._queue.get(timeout=remaining) \
                        if remaining > 0 else self._queue.get_nowait()
                except queue_mod.Empty:
                    cause = "timeout"
                    break
                if nxt is _STOP:
                    stop, cause = True, "forced"
                    break
                if nxt is _FLUSH:
                    cause = "forced"
                    break
                batch.append(nxt)
            try:
                self._launch(batch, cause)
            except BaseException as e:     # never kill the dispatcher
                for req in batch:
                    try:
                        req.future.set_exception(e)
                    except Exception:
                        pass
            if stop:
                return

    def _coalesce(self, batch: List[_StreamRequest]
                  ) -> List[Tuple[List[_StreamRequest],
                                  Dict[str, List[Dict[str, Any]]]]]:
        """Group a dispatch window into sub-batches whose qid → rows
        maps agree: requests sharing a qid with identical rows merge
        (the shared query executes once); a request re-using a qid with
        *different* rows starts a new sub-batch so per-qid semantics
        stay exact."""
        if not self.coalescing:
            return [([req], dict(req.qid_orig)) for req in batch]
        groups: List[Tuple[List[_StreamRequest],
                           Dict[str, List[Dict[str, Any]]]]] = []
        reqs: List[_StreamRequest] = []
        frozen: Dict[str, Tuple] = {}
        orig: Dict[str, List[Dict[str, Any]]] = {}
        for req in batch:
            conflict = any(frozen.get(q) is not None and frozen[q] != rows
                           for q, rows in req.qid_rows.items())
            if conflict and reqs:
                groups.append((reqs, orig))
                reqs, frozen, orig = [], {}, {}
            reqs.append(req)
            for q, rows in req.qid_rows.items():
                frozen.setdefault(q, rows)
                orig.setdefault(q, req.qid_orig[q])
        if reqs:
            groups.append((reqs, orig))
        return groups

    def _launch(self, batch: List[_StreamRequest], cause: str) -> None:
        # groups are isolated: one group failing to build or launch
        # fails only ITS requests — other groups of the window proceed
        for reqs, qid_rows in self._coalesce(batch):
            try:
                self._launch_group(reqs, qid_rows, cause)
            except BaseException as e:
                for req in reqs:
                    try:
                        req.future.set_exception(e)
                    except Exception:
                        pass

    def _launch_group(self, reqs: List[_StreamRequest],
                      qid_rows: Dict[str, List[Dict[str, Any]]],
                      cause: str) -> None:
        rows: List[Dict[str, Any]] = []
        for q in qid_rows:
            rows.extend(qid_rows[q])
        frame = ColFrame.from_dicts(rows)   # before any state mutation
        n_rows_in = sum(len(r.rows) for r in reqs)
        if self._prefetcher is not None:
            # query-keyed store reads start before the batch is even
            # scheduled — they overlap this batch's wave-0 compute (and
            # any other batch in flight)
            self._prefetcher.node_ready(self.graph.source.id, frame)
        with self._lock:
            s = self._seq
            self._seq += 1
            self._results[(self.graph.source.id, s)] = frame
            for node in self._schedulable:
                self._indeg[(node.id, s)] = len(_effective_inputs(node))
            self._meta[s] = _BatchMeta(reqs, cause, n_rows_in)
            self._inflight += 1
            ready = self._complete_locked(self.graph.source.id, s)
        self.stats.record_batch(n_requests=len(reqs), rows_in=n_rows_in,
                                rows_executed=len(frame), cause=cause)
        try:
            for node in ready:
                self._pool.submit(self._run_task, node, s)
        except BaseException as e:
            # pool refused (shutdown race): unwind _inflight and fail
            # this batch's futures so close() never stalls
            self._fail_batch(s, e)

    # -- wavefront -----------------------------------------------------------
    def _complete_locked(self, node_id: int, s: int) -> List[IRNode]:
        ready = []
        for child in self._children.get(node_id, ()):
            key = (child.id, s)
            if key not in self._indeg:
                continue                 # batch already failed/cleaned
            self._indeg[key] -= 1
            if self._indeg[key] == 0:
                ready.append(child)
        return ready

    def _run_task(self, node: IRNode, s: int) -> None:
        with self._lock:
            meta = self._meta.get(s)
        if meta is None or meta.failed:
            return
        cache = node.cache
        # hand-wrapped caches arrive as the *stage* (e.g. the legacy
        # scorer service pipeline `ScorerCache(scorer)`), planner memos
        # as node.cache — count per-call hits from whichever runs
        runner = cache if cache is not None else node.stage
        track = runner is not None and hasattr(runner, "pop_call_counts")
        if track:
            runner.pop_call_counts()     # drop stale counts on this thread
        try:
            t0 = time.perf_counter()
            if node.probe_input is not None and cache is not None:
                out = _exec_with_probe(
                    node, self._results[(node.probe_input.id, s)],
                    self.batch_size, s, _NULL_RECORDER)
            else:
                ins = [self._results[(i.id, s)] for i in node.inputs]
                out = _exec_node(node, ins, self.batch_size)
            dt_ms = (time.perf_counter() - t0) * 1000.0
        except BaseException as e:
            self._fail_batch(s, e)
            return
        hits = misses = 0
        if track:
            hits, misses = runner.pop_call_counts()
            self.stats.add_cache_counts(hits, misses)
        with self._lock:
            if s not in self._meta:      # batch failed & was cleaned up
                return
            self._results[(node.id, s)] = out
            meta.hits += hits
            meta.misses += misses
        if self._prefetcher is not None:
            # doc-keyed caches fed by this node (scorers after a
            # retriever) can start fetching for this batch now
            self._prefetcher.node_ready(node.id, out)
        self.stats.node(node.label).record(dt_ms, rows=len(out))
        if node is self.terminal:
            self._finalize(s, out)
            return
        with self._lock:
            ready = self._complete_locked(node.id, s)
        for child in ready:
            self._pool.submit(self._run_task, child, s)

    # -- completion ----------------------------------------------------------
    def _cleanup_locked(self, s: int) -> Optional[_BatchMeta]:
        meta = self._meta.pop(s, None)
        for k in [k for k in self._results if k[1] == s]:
            del self._results[k]
        for k in [k for k in self._indeg if k[1] == s]:
            del self._indeg[k]
        if meta is not None:
            self._inflight -= 1
            self._idle.notify_all()
        return meta

    def _finalize(self, s: int, out: ColFrame) -> None:
        with self._idle:
            meta = self._cleanup_locked(s)
        if meta is None:
            return
        groups = {str(k[0]): idx for k, idx in
                  out.group_indices(["qid"]).items()} if len(out) else {}
        now = time.perf_counter()
        latencies = []
        for req in meta.requests:
            parts = [out.take(groups[q]) for q in req.qid_order
                     if q in groups]
            res = parts[0] if len(parts) == 1 else (
                ColFrame.concat(parts) if parts else ColFrame())
            latencies.append((now - req.t0) * 1000.0)
            try:                         # a caller may have cancelled;
                req.future.set_result(res)   # never stall its batchmates
            except Exception:
                pass
        if self._on_batch is not None:
            try:
                self._on_batch(n_requests=len(meta.requests),
                               latencies_ms=latencies, cause=meta.cause,
                               cache_hits=meta.hits,
                               cache_misses=meta.misses)
            except Exception:
                pass

    def _fail_batch(self, s: int, err: BaseException) -> None:
        with self._idle:
            meta = self._cleanup_locked(s)
            if meta is not None:
                meta.failed = True
        if meta is None:
            return
        for req in meta.requests:
            try:
                req.future.set_exception(err)
            except Exception:            # already resolved/cancelled
                pass
