"""Declarative experiments (paper §2.2) with prefix precomputation (§3).

``Experiment(systems, topics, qrels, measures, ...)`` invokes each system
on the topics, evaluates with the requested measures, and (optionally)
runs paired significance tests against a baseline with multiple-testing
correction (Fuhr / Sakai guidance cited by the paper).

``precompute_prefix=True`` enables the paper's §3 LCP precomputation;
``precompute_mode="trie"`` enables the beyond-paper maximal-coverage
trie (resolves the §6 ablation limitation).
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from .frame import ColFrame
from .measures import evaluate, parse_measure
from .pipeline import Transformer, stages_of
from .precompute import (PrecomputeStats, longest_common_prefix,
                         run_with_precompute, run_with_trie)

__all__ = ["Experiment", "ExperimentResult"]


# ---------------------------------------------------------------------------
# significance machinery
# ---------------------------------------------------------------------------

def _paired_ttest(a: np.ndarray, b: np.ndarray) -> float:
    """Two-sided paired t-test p-value (scipy if present, else exact
    incomplete-beta evaluation of the t CDF)."""
    d = np.asarray(a, dtype=np.float64) - np.asarray(b, dtype=np.float64)
    n = d.size
    if n < 2:
        return 1.0
    sd = d.std(ddof=1)
    if sd == 0:
        return 1.0
    t = d.mean() / (sd / math.sqrt(n))
    df = n - 1
    try:
        from scipy import stats  # type: ignore
        return float(stats.t.sf(abs(t), df) * 2.0)
    except Exception:
        x = df / (df + t * t)
        return float(_betainc(df / 2.0, 0.5, x))


def _betainc(a: float, b: float, x: float) -> float:
    """Regularized incomplete beta I_x(a,b) via continued fraction."""
    if x <= 0.0:
        return 0.0
    if x >= 1.0:
        return 1.0
    lbeta = math.lgamma(a) + math.lgamma(b) - math.lgamma(a + b)
    front = math.exp(math.log(x) * a + math.log1p(-x) * b - lbeta) / a
    # Lentz's continued fraction
    f, c, d = 1.0, 1.0, 0.0
    for i in range(200):
        m = i // 2
        if i == 0:
            num = 1.0
        elif i % 2 == 0:
            num = m * (b - m) * x / ((a + 2 * m - 1) * (a + 2 * m))
        else:
            num = -(a + m) * (a + b + m) * x / ((a + 2 * m) * (a + 2 * m + 1))
        d = 1.0 + num * d
        d = 1.0 / (d if abs(d) > 1e-30 else 1e-30)
        c = 1.0 + num / (c if abs(c) > 1e-30 else 1e-30)
        f *= c * d
        if abs(1.0 - c * d) < 1e-12:
            break
    val = front * (f - 1.0)
    if x < (a + 1.0) / (a + b + 2.0):
        return min(max(val, 0.0), 1.0)
    return min(max(1.0 - val, 0.0), 1.0)


def _correct(pvals: List[float], method: str) -> List[float]:
    p = np.asarray(pvals, dtype=np.float64)
    m = p.size
    if m == 0:
        return []
    if method in ("bonferroni", "bonf"):
        return list(np.minimum(p * m, 1.0))
    if method in ("holm", "holm-bonferroni"):
        order = np.argsort(p)
        adj = np.empty(m)
        running = 0.0
        for rank, idx in enumerate(order):
            running = max(running, (m - rank) * p[idx])
            adj[idx] = min(running, 1.0)
        return list(adj)
    if method in ("none", None):
        return list(p)
    raise ValueError(f"unknown correction {method!r}")


# ---------------------------------------------------------------------------

@dataclass
class ExperimentResult:
    """Tabular result of an Experiment."""
    names: List[str]
    measures: List[str]
    means: Dict[str, Dict[str, float]]               # name -> measure -> mean
    per_query: Dict[str, Dict[str, Dict[str, float]]]  # name -> measure -> qid -> v
    pvalues: Dict[str, Dict[str, float]] = field(default_factory=dict)
    corrected_pvalues: Dict[str, Dict[str, float]] = field(default_factory=dict)
    times_s: Dict[str, float] = field(default_factory=dict)
    total_time_s: float = 0.0
    precompute: Optional[PrecomputeStats] = None
    results_frames: Optional[List[ColFrame]] = None

    def row(self, name: str) -> Dict[str, float]:
        return dict(self.means[name])

    def to_rows(self) -> List[Dict[str, Any]]:
        rows = []
        for n in self.names:
            r: Dict[str, Any] = {"name": n}
            r.update(self.means[n])
            if n in self.pvalues:
                for m, p in self.pvalues[n].items():
                    r[f"p({m})"] = p
            rows.append(r)
        return rows

    def __str__(self) -> str:
        cols = ["name"] + self.measures
        widths = {c: max(len(c), 12) for c in cols}
        lines = ["  ".join(c.ljust(widths[c]) for c in cols)]
        for n in self.names:
            vals = [n.ljust(widths["name"])]
            for m in self.measures:
                vals.append(f"{self.means[n][m]:.4f}".ljust(widths[m]))
            lines.append("  ".join(vals))
        return "\n".join(lines)


def Experiment(
    systems: Sequence[Transformer],
    topics: Any,
    qrels: Any,
    measures: Sequence,
    *,
    names: Optional[Sequence[str]] = None,
    precompute_prefix: bool = False,
    precompute_mode: str = "lcp",          # "lcp" (§3) | "trie" | "plan"
    cache_dir: Optional[str] = None,       # plan mode: auto-insert caches
    cache_backend: Optional[str] = None,   # plan mode: backend registry name
    on_stale: str = "error",               # plan mode: stale-cache policy
    optimize: Any = "all",                 # plan mode: optimizer pass knob
    n_shards: Optional[int] = None,        # plan mode: concurrent executor
    max_workers: Optional[int] = None,
    baseline: Optional[int] = None,
    correction: str = "holm",
    batch_size: Optional[int] = None,
    keep_results: bool = False,
    verbose: bool = False,
) -> ExperimentResult:
    """Evaluate ``systems`` on ``topics`` against ``qrels``.

    Mirrors the paper's ``pt.Experiment`` signature: systems, topics
    (type Q), qrels (type RA), measures; plus ``precompute_prefix``
    (§3), significance testing wrt. ``baseline`` with multiple-testing
    ``correction`` (Fuhr/Sakai), and ``batch_size``.

    ``precompute_mode`` selects the sharing strategy: ``"lcp"`` reports
    the paper-§3 accounting, ``"trie"`` maximal prefix sharing, and
    ``"plan"`` the full execution planner (``core/plan.py``) — which
    additionally shares through binary operator nodes and, given a
    ``cache_dir``, auto-inserts the §4 explicit caches per DAG node
    (``cache_backend`` selects their storage backend; ``on_stale``
    picks the policy when a cache directory's recorded provenance
    fingerprint mismatches — see ``caching/provenance.py``).  In plan mode
    ``n_shards`` / ``max_workers`` enable the concurrent sharded
    executor and ``optimize`` selects the optimizer passes
    (``"all"`` / ``"none"`` / list of names — see ``core/rewrite.py``).
    All three execute through the planner; results are identical.
    """
    topics = ColFrame.coerce(topics)
    qrels = ColFrame.coerce(qrels)
    measures = [parse_measure(m) for m in measures]
    systems = list(systems)
    if names is None:
        names = [repr(s) for s in systems]
    names = [str(n) for n in names]
    if len(names) != len(systems):
        raise ValueError("names must align with systems")

    t0 = time.perf_counter()
    stats: Optional[PrecomputeStats] = None
    times: Dict[str, float] = {}

    if precompute_prefix and len(systems) > 1:
        if precompute_mode == "plan":
            from .plan import ExecutionPlan
            with ExecutionPlan(systems, cache_dir=cache_dir,
                               cache_backend=cache_backend,
                               on_stale=on_stale, optimize=optimize) as plan:
                outs, stats = plan.run(topics, batch_size=batch_size,
                                       n_shards=n_shards,
                                       max_workers=max_workers)
        elif precompute_mode == "trie":
            outs, stats = run_with_trie(systems, topics,
                                        batch_size=batch_size,
                                        n_shards=n_shards,
                                        max_workers=max_workers)
        elif precompute_mode == "lcp":
            outs, stats = run_with_precompute(systems, topics,
                                              batch_size=batch_size,
                                              n_shards=n_shards,
                                              max_workers=max_workers)
        else:
            raise ValueError(f"unknown precompute_mode {precompute_mode!r}; "
                             f"expected 'lcp', 'trie' or 'plan'")
        # per-system times are not separable under sharing; record totals only
        for n in names:
            times[n] = float("nan")
    else:
        outs = []
        for s, n in zip(systems, names):
            ts = time.perf_counter()
            if batch_size is None or len(topics) <= batch_size:
                outs.append(s(topics))
            else:
                parts = [s(topics.take(range(lo, min(lo + batch_size,
                                                     len(topics)))))
                         for lo in range(0, len(topics), batch_size)]
                outs.append(ColFrame.concat(parts))
            times[n] = time.perf_counter() - ts
            if verbose:
                print(f"[experiment] {n}: {times[n]:.3f}s")

    per_query: Dict[str, Dict[str, Dict[str, float]]] = {}
    means: Dict[str, Dict[str, float]] = {}
    for n, res in zip(names, outs):
        pq = evaluate(res, qrels, measures)
        per_query[n] = pq
        means[n] = {m.name: (float(np.mean(list(pq[m.name].values())))
                             if pq[m.name] else 0.0)
                    for m in measures}

    result = ExperimentResult(
        names=names, measures=[m.name for m in measures], means=means,
        per_query=per_query, times_s=times,
        total_time_s=time.perf_counter() - t0, precompute=stats,
        results_frames=list(outs) if keep_results else None)

    if baseline is not None:
        base_name = names[baseline]
        raw_all: List[Tuple[str, str, float]] = []
        for n in names:
            if n == base_name:
                continue
            result.pvalues[n] = {}
            for m in result.measures:
                qids = sorted(per_query[base_name][m])
                a = np.array([per_query[n][m].get(q, 0.0) for q in qids])
                b = np.array([per_query[base_name][m][q] for q in qids])
                p = _paired_ttest(a, b)
                result.pvalues[n][m] = p
                raw_all.append((n, m, p))
        corrected = _correct([p for _, _, p in raw_all], correction)
        for (n, m, _), cp in zip(raw_all, corrected):
            result.corrected_pvalues.setdefault(n, {})[m] = cp
    return result
