"""Cost layer under the plan compiler.

The rewrite passes of ``core/rewrite.py`` fired on *structure* alone
through PR 8: cache placement, operand order and the serving
micro-batch knobs were hand-tuned.  This module gives the optimizer a
:class:`CostModel` blending three signal sources, in decreasing order
of trust:

* **measured** — per-node recompute costs of previous runs
  (``PlanStats.node_times_s`` for uncached nodes; the raw miss-path
  compute channel ``node_compute_s`` for cached ones, so store round
  trips never masquerade as compute), folded back into the plan
  manifest on every run as an exponentially-weighted moving average
  keyed by node *fingerprint*.
  Keying by provenance fingerprint means measured costs survive
  restarts for exactly as long as they are valid: a config or code
  change anywhere upstream changes the fingerprint and the stale
  measurement is simply never looked up again.
* **analytic** — ``launch/roofline.py`` host-roofline estimates for
  kernel-backed stages (dense top-k matmul, BM25 postings traversal),
  the cold-start prior before anything has been measured.
* **default** — small per-kind constants so every node has *some*
  estimate.  Defaults are deliberately weak evidence: cost-aware
  rewrites that can lose work (cache skipping) refuse to fire on them.

:class:`CostContext` packages the model with the plan's node
fingerprints and the measured cache round-trip cost of the selected
backend (``caching.backends.measure_round_trip``); ``ExecutionPlan``
attaches it to the graph as ``graph.cost`` for the cost-aware passes
(``operand-order`` / ``cache-place`` / ``autotune``).

Invariant: costs influence *scheduling, placement and knobs* only —
never results.  Plans compiled with and without a cost context are
per-qid bit-identical (property-tested in ``tests/test_cost.py``).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

from .ir import IRNode, PlanGraph

__all__ = ["CostModel", "CostContext", "compute_node_fingerprints",
           "fold_costs", "annotate_node_actuals", "analytic_stage_cost",
           "should_prefetch", "PREFETCH_MIN_ROUND_TRIP_S",
           "EWMA_ALPHA", "DEFAULT_STAGE_COST_S", "DEFAULT_COMBINE_COST_S"]

#: EWMA weight of the newest observation (0.4 ≈ the last ~4 runs carry
#: ~87% of the weight — adapts quickly without thrashing on one outlier)
EWMA_ALPHA = 0.4

#: per-query default priors (seconds) — weak evidence, see module doc
DEFAULT_STAGE_COST_S = 2e-4
DEFAULT_COMBINE_COST_S = 2e-5

#: cost figures are rounded before persisting / rendering so the
#: in-process explain() and the JSON-round-tripped CLI agree exactly
COST_DECIMALS = 9


def _round_cost(x: float) -> float:
    return round(float(x), COST_DECIMALS)


def compute_node_fingerprints(graph: PlanGraph) -> Dict[int, str]:
    """Provenance fingerprint per node (id-keyed): the stage fingerprint
    folded over the input nodes' fingerprints, bottom-up.

    For *commutative* combine nodes the input fingerprints fold in
    sorted order, so ``a + b`` and ``b + a`` — and a combine whose
    operands the ``operand-order`` pass swapped — carry the same
    fingerprint.  This keeps measured costs (and cache-manifest
    provenance) stable under the one rewrite that is allowed to change
    physical operand order without changing results.
    """
    from ..caching.auto import derive_fingerprint
    from ..caching.provenance import combine_fingerprints
    fps: Dict[int, str] = {
        graph.source.id: combine_fingerprints("plan-source")}
    # graph.nodes is topological — every input precedes its consumer
    for node in graph.nodes:
        if node.kind == "source":
            continue
        in_fps = [fps[i.id] for i in node.inputs]
        if node.kind == "combine" and getattr(node.stage, "commutative",
                                              False):
            # the binary stage's own signature() embeds its operands'
            # signatures *in order*; the operands are already captured
            # by the (sorted) input fingerprints, so key the stage by
            # class alone — same symmetrization canon_key uses
            stage_fp = combine_fingerprints("combine",
                                            type(node.stage).__name__)
            in_fps = sorted(in_fps)
        else:
            stage_fp = derive_fingerprint(node.stage) \
                or combine_fingerprints("sig", repr(node.stage))
        fps[node.id] = combine_fingerprints(
            "node", node.kind, stage_fp, *in_fps)
    return fps


def analytic_stage_cost(stage: Any) -> Optional[float]:
    """Roofline cold-start prior for kernel-backed stages (per-query
    seconds); ``None`` for stages the roofline cannot model."""
    try:
        from ..launch.roofline import estimate_stage_cost
    except Exception:
        return None
    try:
        return estimate_stage_cost(stage)
    except Exception:
        return None


class CostModel:
    """Measured per-node costs, EWMA-folded per node fingerprint.

    The table lives in the plan manifest (``costs`` key) so it survives
    restarts; entries go stale *with provenance* — a changed upstream
    fingerprint is a different key, never a wrong answer.
    """

    def __init__(self, measured: Optional[Dict[str, Dict[str, Any]]] = None):
        self.measured: Dict[str, Dict[str, Any]] = dict(measured or {})

    @classmethod
    def from_manifest(cls, record: Optional[Dict[str, Any]]) -> "CostModel":
        """Rebuild the model from a plan-manifest record (tolerant of
        missing/garbled entries — a cost table is advisory data)."""
        out: Dict[str, Dict[str, Any]] = {}
        costs = (record or {}).get("costs") or {}
        if isinstance(costs, dict):
            for fp, ent in costs.items():
                try:
                    parsed = {
                        "s_per_query": float(ent["s_per_query"]),
                        "n": int(ent.get("n", 1)),
                        "updated_at": float(ent.get("updated_at", 0.0)),
                    }
                    if ent.get("cache_s_per_query") is not None:
                        parsed["cache_s_per_query"] = \
                            float(ent["cache_s_per_query"])
                    out[str(fp)] = parsed
                except (TypeError, KeyError, ValueError):
                    continue
        return cls(out)

    def measured_cost(self, fp: Optional[str]) -> Optional[float]:
        ent = self.measured.get(fp) if fp else None
        return float(ent["s_per_query"]) if ent else None

    def measured_cache_cost(self, fp: Optional[str]) -> Optional[float]:
        """Measured per-query cost of the node's *cache path* (store
        lookups, inserts, [de]serialization — wrapper wall time minus
        raw compute).  The apples-to-apples alternative the cache-place
        pass weighs recompute against: a query may touch many store
        entries, so a per-entry round-trip figure understates it."""
        ent = self.measured.get(fp) if fp else None
        v = ent.get("cache_s_per_query") if ent else None
        return float(v) if v is not None else None

    def observe(self, fp: str, s_per_query: float) -> None:
        """Fold one run's per-query cost for the node ``fp`` into the
        EWMA (first observation seeds the average)."""
        s_per_query = max(0.0, float(s_per_query))
        ent = self.measured.get(fp)
        if ent is None:
            self.measured[fp] = {"s_per_query": _round_cost(s_per_query),
                                 "n": 1, "updated_at": time.time()}
            return
        ewma = (EWMA_ALPHA * s_per_query
                + (1.0 - EWMA_ALPHA) * float(ent["s_per_query"]))
        ent["s_per_query"] = _round_cost(ewma)
        ent["n"] = int(ent.get("n", 1)) + 1
        ent["updated_at"] = time.time()

    def observe_cache(self, fp: str, s_per_query: float) -> None:
        """Fold one run's per-query cache-path cost for the node ``fp``
        (no-op until a recompute cost has been observed: the entry is
        keyed by it)."""
        s_per_query = max(0.0, float(s_per_query))
        ent = self.measured.get(fp)
        if ent is None:
            return
        prev = ent.get("cache_s_per_query")
        if prev is None:
            ent["cache_s_per_query"] = _round_cost(s_per_query)
        else:
            ent["cache_s_per_query"] = _round_cost(
                EWMA_ALPHA * s_per_query + (1.0 - EWMA_ALPHA) * float(prev))

    def to_manifest(self) -> Dict[str, Dict[str, Any]]:
        out: Dict[str, Dict[str, Any]] = {}
        for fp, ent in self.measured.items():
            d = {"s_per_query": _round_cost(ent["s_per_query"]),
                 "n": int(ent.get("n", 1)),
                 "updated_at": float(ent.get("updated_at", 0.0))}
            if ent.get("cache_s_per_query") is not None:
                d["cache_s_per_query"] = _round_cost(ent["cache_s_per_query"])
            out[fp] = d
        return out


@dataclass
class CostContext:
    """Everything a cost-aware pass needs, attached as ``graph.cost``."""

    model: CostModel = field(default_factory=CostModel)
    #: node id → provenance fingerprint (``compute_node_fingerprints``)
    fps: Dict[int, str] = field(default_factory=dict)
    #: resolved backend selector of planner-inserted caches, if any
    backend: Optional[str] = None
    #: measured per-entry cache round-trip of ``backend`` (seconds);
    #: ``None`` when no caches will be inserted (cache-place no-ops)
    round_trip_s: Optional[float] = None
    #: run history from the prior plan manifest (autotune evidence)
    history: List[Dict[str, Any]] = field(default_factory=list)
    _subtree: Dict[int, float] = field(default_factory=dict, repr=False)

    def estimate(self, node: IRNode) -> Tuple[float, str]:
        """Per-query cost estimate for one node and the source of the
        figure: ``"measured"`` > ``"analytic"`` > ``"default"``."""
        m = self.model.measured_cost(self.fps.get(node.id))
        if m is not None:
            return _round_cost(m), "measured"
        if node.kind == "stage":
            a = analytic_stage_cost(node.stage)
            if a is not None:
                return _round_cost(a), "analytic"
            return DEFAULT_STAGE_COST_S, "default"
        return DEFAULT_COMBINE_COST_S, "default"

    def subtree_cost(self, node: IRNode) -> float:
        """Estimated cost of the whole subtree rooted at ``node`` (the
        operand-order pass compares these).  Shared nodes count once
        per reachable path — an upper bound, which is the conservative
        direction for ordering decisions."""
        c = self._subtree.get(node.id)
        if c is None:
            c = self.estimate(node)[0] if node.kind != "source" else 0.0
            for inp in node.inputs:
                c += self.subtree_cost(inp)
            self._subtree[node.id] = c
        return c

    def invalidate_subtrees(self) -> None:
        """Drop memoized subtree costs (after a structural rewrite)."""
        self._subtree.clear()


#: per-entry store round trip (seconds) below which a backend behaves
#: like memory — moving its reads to the I/O pool would only add
#: handoff overhead, so the prefetch gate refuses to stamp such nodes
PREFETCH_MIN_ROUND_TRIP_S = 2e-6


def should_prefetch(round_trip_s: Optional[float], *,
                    overlap_s: Optional[float] = None) -> bool:
    """Cost gate for the asynchronous data plane: is issuing a node's
    warm-path store reads on the background I/O pool worth it?

    * ``round_trip_s`` — measured per-entry round trip of the selected
      backend (``caching.backends.measure_round_trip``); ``None`` means
      unmeasured, which passes the gate — the backend's own
      ``prefetchable`` flag already vetoes memory-speed tiers, so an
      unknown figure is presumed disk-like.
    * ``overlap_s`` — optional estimate of the compute window the fetch
      would hide behind (e.g. wave-0's estimated cost).  When provided
      and ≤ 0 there is nothing to overlap with, so the gate refuses.

    Like every cost decision this influences scheduling only: prefetch
    on/off is per-qid bit-identical (property-tested in
    ``tests/test_dataplane.py``).
    """
    if overlap_s is not None and overlap_s <= 0.0:
        return False
    if round_trip_s is None:
        return True
    return float(round_trip_s) >= PREFETCH_MIN_ROUND_TRIP_S


def fold_costs(record: Dict[str, Any], stats: Any) -> None:
    """Fold one run's measured per-node costs into ``record`` (the
    plan-manifest dict): update the fingerprint-keyed EWMA table and
    re-annotate every node's ``cost_act_s``.  Mutates ``record``.

    The EWMA tracks the cost to *recompute* a node per query.  For
    cached nodes the run's wall time is dominated by store round trips,
    so the raw miss-path compute channel
    (``PlanStats.node_compute_s`` / ``node_compute_queries``) is used
    instead — and an all-hit run, which recomputed nothing, contributes
    no observation at all rather than a near-zero one.  Uncached nodes
    fold their wall time over the run's query count as before."""
    nodes = record.get("nodes") or []
    fp_by_label = {n.get("label"): n.get("fingerprint") for n in nodes}
    n_queries = max(1, int(getattr(stats, "n_queries", 0) or 0))
    compute_s = getattr(stats, "node_compute_s", None) or {}
    compute_q = getattr(stats, "node_compute_queries", None) or {}
    model = CostModel.from_manifest(record)
    for label, total_s in (getattr(stats, "node_times_s", None) or {}).items():
        fp = fp_by_label.get(label)
        if not fp:
            continue
        if label in compute_q:           # cached node: raw recomputes only
            cq = int(compute_q.get(label, 0))
            raw_s = float(compute_s.get(label, 0.0))
            if cq > 0:
                model.observe(fp, raw_s / cq)
            # the remainder of the wrapper's wall time is the cache
            # path itself — what cache-place weighs recompute against
            model.observe_cache(fp, max(0.0, float(total_s) - raw_s)
                                / n_queries)
            continue
        model.observe(fp, float(total_s) / n_queries)
    record["costs"] = model.to_manifest()
    annotate_node_actuals(record, model)


def annotate_node_actuals(record: Dict[str, Any],
                          model: Optional[CostModel] = None) -> None:
    """Set each node dict's ``cost_act_s`` from the manifest's measured
    EWMA table — what explain()'s est-vs-actual columns render."""
    if model is None:
        model = CostModel.from_manifest(record)
    for n in record.get("nodes") or []:
        act = model.measured_cost(n.get("fingerprint"))
        if act is not None:
            n["cost_act_s"] = _round_cost(act)
