"""Generic train-step factory + host-side training loop.

``make_train_step(loss_fn, opt_cfg, ...)`` builds a pjit-able

    (params, opt_state, batch) -> (params, opt_state, metrics)

with optional microbatch gradient accumulation (scan over micro-slices;
the per-microbatch all-reduce becomes one accumulation + one update —
the compute/comm overlap then falls to XLA's latency-hiding scheduler,
which the layer-scan structure is shaped for) and optional gradient
compression with error feedback.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..distrib.compression import CompressionConfig, compress_grads, \
    init_ef_state
from .optimizer import AdamWConfig, adamw_init, adamw_update
from .schedule import constant

__all__ = ["make_train_step", "train_loop"]


def make_train_step(loss_fn: Callable, opt_cfg: AdamWConfig,
                    *, lr_schedule: Callable = constant,
                    microbatches: int = 1,
                    compression: Optional[CompressionConfig] = None):
    """loss_fn(params, batch) -> scalar loss."""
    compression = compression or CompressionConfig()
    use_ef = compression.method != "none"

    def split_micro(batch, i):
        def slice_one(x):
            mb = x.shape[0] // microbatches
            return jax.lax.dynamic_slice_in_dim(x, i * mb, mb, axis=0)
        return jax.tree.map(slice_one, batch)

    def train_step(params, opt_state, batch):
        if microbatches > 1:
            def acc_body(carry, i):
                acc, = carry
                mb = split_micro(batch, i)
                loss, grads = jax.value_and_grad(loss_fn)(params, mb)
                acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32), acc, grads)
                return (acc,), loss
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum,), losses = jax.lax.scan(acc_body, (zeros,),
                                           jnp.arange(microbatches))
            grads = jax.tree.map(lambda g: g / microbatches, gsum)
            loss = losses.mean()
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)

        if use_ef:
            ef = opt_state["ef"]
            grads, ef = compress_grads(grads, ef, compression)
        lr_scale = lr_schedule(opt_state["adam"]["step"])
        new_params, adam, om = adamw_update(params, grads,
                                            opt_state["adam"], opt_cfg,
                                            lr_scale)
        new_opt = {"adam": adam}
        if use_ef:
            new_opt["ef"] = ef
        metrics = {"loss": loss, "lr_scale": lr_scale, **om}
        return new_params, new_opt, metrics

    def init_opt(params):
        opt = {"adam": adamw_init(params, opt_cfg.moment_dtype)}
        if use_ef:
            opt["ef"] = init_ef_state(params)
        return opt

    return train_step, init_opt


def train_loop(params, batch_fn: Callable[[int], Any], loss_fn: Callable,
               *, n_steps: int, opt_cfg: Optional[AdamWConfig] = None,
               microbatches: int = 1,
               compression: Optional[CompressionConfig] = None,
               log_every: int = 10, jit: bool = True):
    """Single-host convenience loop (examples/tests). Returns
    (params, opt_state, history)."""
    opt_cfg = opt_cfg or AdamWConfig()
    step_fn, init_opt = make_train_step(
        loss_fn, opt_cfg, microbatches=microbatches, compression=compression)
    if jit:
        step_fn = jax.jit(step_fn)
    opt_state = init_opt(params)
    history = []
    for step in range(n_steps):
        batch = batch_fn(step)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if step % log_every == 0 or step == n_steps - 1:
            history.append({"step": step,
                            **{k: float(v) for k, v in metrics.items()}})
    return params, opt_state, history
