"""LR schedules (pure functions of step, usable inside jit)."""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["linear_warmup_cosine", "constant"]


def constant(step, *, value: float = 1.0):
    return jnp.asarray(value, jnp.float32)


def linear_warmup_cosine(step, *, warmup: int = 100, total: int = 10000,
                         floor: float = 0.1):
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
    progress = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1),
                        0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * progress))
    return warm * (floor + (1.0 - floor) * cos)
