from .optimizer import AdamWConfig, adamw_init, adamw_update, global_norm, \
    adamw_state_specs
from .schedule import linear_warmup_cosine, constant
from .loop import make_train_step, train_loop

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "global_norm",
           "adamw_state_specs", "linear_warmup_cosine", "constant",
           "make_train_step", "train_loop"]
