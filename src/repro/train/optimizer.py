"""AdamW in pure JAX (no optax dependency).

Optimizer state mirrors the parameter pytree (m, v in fp32), so it
inherits the parameters' shardings — the ZeRO-style sharded-optimizer
layout falls out of the logical-axis rules for free.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "global_norm",
           "adamw_state_specs"]


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    #: dtype of the m/v moments. fp32 is the safe default; bf16 halves
    #: optimizer-state HBM footprint AND traffic (the 8-bit-Adam family
    #: of tricks, conservative variant) — found in §Perf hillclimbing.
    moment_dtype: Any = jnp.float32


def adamw_init(params, moment_dtype=jnp.float32) -> Dict:
    zeros = lambda p: jnp.zeros(p.shape, moment_dtype)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def adamw_state_specs(param_specs, moment_dtype=jnp.float32) -> Dict:
    """ParamSpec tree for the optimizer state (moments + step)."""
    from ..models.common import ParamSpec

    def mom(s: ParamSpec) -> ParamSpec:
        return ParamSpec(s.shape, s.logical_axes, moment_dtype,
                         init="zeros")

    as_mom = jax.tree.map(mom, param_specs,
                          is_leaf=lambda x: isinstance(x, ParamSpec))
    return {"m": as_mom,
            "v": jax.tree.map(lambda s: s, as_mom,
                              is_leaf=lambda x: isinstance(x, ParamSpec)),
            "step": ParamSpec((), (), jnp.int32, init="zeros")}


def global_norm(tree) -> jnp.ndarray:
    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def adamw_update(params, grads, state, cfg: AdamWConfig,
                 lr_scale: jnp.ndarray | float = 1.0):
    """One AdamW step with global-norm clipping. Returns (params, state,
    metrics)."""
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t
    lr = cfg.lr * lr_scale

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        mh = m32 / bc1
        vh = v32 / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) \
            + cfg.weight_decay * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr * delta).astype(p.dtype),
                m32.astype(cfg.moment_dtype), v32.astype(cfg.moment_dtype))

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_flatten(grads)[0]
    flat_m = jax.tree_util.tree_flatten(state["m"])[0]
    flat_v = jax.tree_util.tree_flatten(state["v"])[0]
    new_p, new_m, new_v = [], [], []
    for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v):
        np_, nm, nv = upd(p, g, m, v)
        new_p.append(np_)
        new_m.append(nm)
        new_v.append(nv)
    unf = lambda xs: jax.tree_util.tree_unflatten(treedef, xs)
    new_state = {"m": unf(new_m), "v": unf(new_v), "step": step}
    return unf(new_p), new_state, {"grad_norm": gnorm}
